//! The shared online memoization tier: per-layer sharded, concurrently
//! readable, writable at serve time.
//!
//! PR 1's online overlay lived inside the engine behind one
//! `Arc<Mutex<Engine>>`; PR 2 extracted it into per-layer `RwLock` shards
//! so lookups parallelized — but an admission still held a shard's write
//! lock for the whole batch (HNSW inserts included), stalling exactly the
//! readers the paper says must stay fast. [`MemoTier`] now uses a
//! **seqlock-published copy-on-write** design, so admissions never block
//! readers at all:
//!
//! * **Per-layer shards**, each publishing an immutable
//!   [`LayerDb`] snapshot through an `Arc` cell guarded by a pointer-swap
//!   `RwLock` plus an atomic **sequence counter** (even = stable, odd = a
//!   publish in flight). A reader's only shared-state touch is cloning
//!   the `Arc` — nanoseconds — after which the whole lookup, epoch-checked
//!   payload read and copy run against the frozen snapshot with **no lock
//!   held**. The worst a reader can ever wait for is one pointer swap.
//! * **Writers serialize on a per-shard mutex**: `admit_batch`, eviction,
//!   compaction and warm restore clone the current snapshot (tables and
//!   index only — payload bytes are shared), mutate the private copy with
//!   the exact same `LayerDb` logic as before, and publish it with a
//!   `seq` bump around the swap.
//! * **Dedup prepass / publish-skip**: before paying the copy-on-write
//!   clone, `admit_batch` probes the *published* snapshot; when every row
//!   of the batch dedups against stored entries (the steady-state case
//!   once a workload's clusters are warm), the batch is served by reuse
//!   marks alone — no clone, no publish, no retiree churn. The skip path
//!   still refreshes the shard's stat gauges, so `STATS` stays live under
//!   pure-dedup traffic.
//! * **Epoch-based slot reclaim, bounded**: an eviction retires its arena
//!   page slot to a *pending* list instead of reusing it. Superseded
//!   snapshots go onto a per-shard retire list together with the slots
//!   their replacement freed; a slot recycles only once every snapshot
//!   that could still reference it has quiesced (its `Arc` count drained
//!   — and retirement order is respected, so a slot outlives *every*
//!   older reader). A stalled reader can therefore delay reclamation but
//!   not unboundedly: past [`MemoTier::retire_cap`] generations the
//!   oldest retirees are *force-reclaimed* (a high-water counter warns
//!   first), and correctness falls back to epoch-stamp validation — the
//!   arena bumps a slot's shared tenancy epoch before its next tenant's
//!   bytes land, so the stalled reader's stamps stop validating and its
//!   fetches turn into clean misses, never foreign bytes.
//! * **Optimistic reads with retry**: readers validate payload fetches
//!   against the arena's generation/tenancy-epoch stamps
//!   (`ApmArena::get_checked`) and *revalidate after copying*
//!   (`ApmArena::recheck`), the seqlock read discipline that makes the
//!   forced-reclaim fallback safe. Within one snapshot a torn read only
//!   happens when a forced reclaim raced the copy; on a stamp failure the
//!   reader consults the shard's sequence counter — changed means "retry
//!   against the fresh snapshot", unchanged means the entry is genuinely
//!   gone.
//! * **Lock-free stats**: `layer_len`/`total_entries`/`resident_bytes`
//!   read per-shard atomics refreshed at publish (and publish-skip) time
//!   instead of walking every shard's lock.
//! * **Cold spill tier** (optional — [`MemoTier::with_cold_tier`]):
//!   clock victims demote out of the hot arena into a file-backed cold
//!   arena (`memo/cold.rs`) on the writer path, under the same shard
//!   mutex that evicted them; a hot-snapshot miss falls through to a
//!   cold probe, and a qualifying cold hit *promotes* back into the hot
//!   tier through the ordinary [`MemoTier::admit_batch`] path. Cold
//!   payload reads validate the same tenancy-epoch stamps as hot ones,
//!   so a racing promotion can never serve foreign bytes; see the
//!   `cold` module docs for the on-disk format and crash-recovery
//!   story.
//!
//! Since PR 6 a steady-state hit acquires **no mutex or rwlock
//! anywhere**: the reuse track is chunked atomics (`attdb.rs`), so a held
//! [`ShardReader`]'s search + epoch-checked copy + reuse mark touch locks
//! zero times, and the snapshot `Arc` itself is served from a
//! **thread-local cache** validated against the shard's sequence counter
//! — only the first read after a publish refreshes it under the pointer-
//! swap read lock. With the dedup prepass suppressing steady-state
//! publishes, the sequence counter goes quiet and the whole hit path is
//! snapshot-Arc load + atomics, end to end.
//!
//! Warm state survives restarts through `memo::persist::{save_warm,
//! load_warm}` (see `docs/PERSISTENCE.md`); a snapshot save quiesces the
//! shard's *writer* only — readers keep serving throughout.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::config::{MemoConfig, ModelConfig};
use crate::memo::arena::StoreHandle;
use crate::memo::attdb::{LayerDb, Lookup};
use crate::memo::cold::ColdTier;
use crate::memo::index::HnswParams;
use crate::memo::policy::{AdmissionPolicy, LayerProfile};
use crate::{Error, Result};

/// What one batched admission did (per layer shard).
#[derive(Debug, Clone, Copy, Default)]
pub struct TierAdmitOutcome {
    /// Rows stored in the shard.
    pub admitted: u64,
    /// Entries evicted by the capacity budget to make room.
    pub evicted: u64,
    /// Rows skipped because a near-identical entry (often from the same
    /// batch) was already stored.
    pub deduped: u64,
    /// Eviction victims demoted into the cold tier instead of dropped
    /// (0 without a cold tier; never exceeds `evicted`).
    pub demoted: u64,
}

/// One layer shard: a seqlock-published snapshot plus its writer state.
struct Shard {
    /// Publish sequence: even = stable, odd = a swap is in flight. Bumped
    /// with `AcqRel`/`Release` around every publish so optimistic readers
    /// can tell "retry against a newer snapshot" from "genuinely gone".
    seq: AtomicU64,
    /// The published snapshot. The lock is held only long enough to clone
    /// or swap the `Arc` — never across a search, copy or mutation.
    snap: RwLock<Arc<LayerDb>>,
    /// Serializes mutations (admission, eviction, compaction, restore)
    /// and owns the epoch-reclaim list.
    writer: Mutex<ShardWriter>,
    /// Live entries in the published snapshot (lock-free stats).
    len: AtomicUsize,
    /// Resident arena bytes of the published snapshot (lock-free stats).
    resident: AtomicUsize,
}

/// Retire-list depth at which the tier starts counting (and once warns)
/// that a stalled reader is delaying snapshot reclamation.
const RETIRE_HIGH_WATER: usize = 8;

/// Hard bound on retired-but-unreclaimed snapshot generations per shard.
/// Publishing past this force-reclaims the oldest retirees even if a
/// reader still pins them — safe because the arena's shared tenancy
/// epochs invalidate that reader's stamps the moment a recycled slot is
/// claimed by a new tenant (see `ApmArena::recheck`).
const RETIRE_CAP: usize = 16;

/// Writer-side state: superseded snapshots awaiting reader quiescence.
#[derive(Default)]
struct ShardWriter {
    /// `(snapshot, store the freed slots live on, slots freed by the
    /// mutation that replaced it)`, in retirement order. The head
    /// recycles once its `Arc` count shows no reader holds it; stopping
    /// at the first live entry guarantees a freed slot outlives every
    /// snapshot old enough to reference it. The store handle is the
    /// *publishing* copy's store (an intra-batch compaction moves the
    /// lineage to a fresh store mid-mutation, so the displaced snapshot's
    /// store may differ from the one the slots were freed on).
    retired: Vec<(Arc<LayerDb>, StoreHandle, Vec<u32>)>,
}

/// Outcome of one optimistic read attempt against a snapshot.
enum ReadAttempt {
    /// Entry found, payload copied, reuse marked.
    Hit(Lookup),
    /// No entry clears the similarity floor.
    Miss,
    /// The epoch stamp failed to validate mid-read.
    Torn,
}

/// A frozen, internally consistent view of one layer shard.
///
/// Every operation against a `ShardReader` — index search, epoch stamp,
/// payload copy — resolves against one publish epoch, so a batch of rows
/// can share one snapshot without per-row revalidation and without
/// holding any lock. Admissions by other replicas publish *new* snapshots;
/// they never mutate this one (displaced arena slots are reclaimed only
/// after this reader drops).
pub struct ShardReader {
    db: Arc<LayerDb>,
    apm_elems: usize,
}

impl ShardReader {
    /// Whether the snapshot holds no entries.
    pub fn is_empty(&self) -> bool {
        self.db.is_empty()
    }

    /// Live entries in the snapshot.
    pub fn len(&self) -> usize {
        self.db.len()
    }

    /// Nearest stored entry for a query (see [`MemoTier::lookup`]).
    pub fn lookup(&self, feature: &[f32], ef: usize) -> Option<Lookup> {
        self.db.lookup(feature, ef)
    }

    /// Search + similarity gate + epoch-checked payload copy + reuse
    /// mark, all against this snapshot.
    fn fetch(&self, feature: &[f32], ef: usize, min_similarity: f32,
             dst: &mut [f32]) -> ReadAttempt {
        let Some(hit) = self.db.lookup(feature, ef) else {
            return ReadAttempt::Miss;
        };
        if hit.similarity < min_similarity {
            return ReadAttempt::Miss;
        }
        match self.db.arena().copy_checked(hit.id, hit.epoch, dst) {
            Ok(()) => {
                // Post-copy revalidation (seqlock read discipline): a
                // forced slot reclaim on the writer side (retire-cap
                // overflow) can overwrite the slot while the copy runs;
                // the copy itself goes through word atomics (so the race
                // is defined behavior) and the tenancy-epoch recheck turns
                // it into a clean torn read instead of serving the next
                // tenant's bytes.
                if !self.db.arena().recheck(hit.id, hit.epoch) {
                    return ReadAttempt::Torn;
                }
                self.db.mark_reused(hit.id);
                ReadAttempt::Hit(hit)
            }
            Err(_) => ReadAttempt::Torn,
        }
    }

    /// Lazy-buffer variant of [`ShardReader::fetch`]: `buf` is zero-filled
    /// to `rows` rows only once a lookup clears the similarity gate (so
    /// misses and below-floor probes stay allocation-free), then row
    /// `row` is filled.
    fn fetch_lazy(&self, feature: &[f32], ef: usize, min_similarity: f32,
                  buf: &mut Vec<f32>, rows: usize,
                  row: usize) -> ReadAttempt {
        let Some(hit) = self.db.lookup(feature, ef) else {
            return ReadAttempt::Miss;
        };
        if hit.similarity < min_similarity {
            return ReadAttempt::Miss;
        }
        if buf.is_empty() {
            buf.resize(rows * self.apm_elems, 0.0);
        }
        let dst =
            &mut buf[row * self.apm_elems..(row + 1) * self.apm_elems];
        match self.db.arena().copy_checked(hit.id, hit.epoch, dst) {
            Ok(()) => {
                // Post-copy revalidation — see [`ShardReader::fetch`]. A
                // torn row is re-zeroed so a miss verdict never leaves
                // another tenant's bytes behind in the batch buffer.
                if !self.db.arena().recheck(hit.id, hit.epoch) {
                    dst.fill(0.0);
                    return ReadAttempt::Torn;
                }
                self.db.mark_reused(hit.id);
                ReadAttempt::Hit(hit)
            }
            Err(_) => {
                dst.fill(0.0);
                ReadAttempt::Torn
            }
        }
    }

    /// Atomic lookup + payload fetch against this snapshot (the per-row
    /// form of [`MemoTier::lookup_fetch`]). A torn read surfaces as a
    /// miss: it means this snapshot outlived the retire cap and the
    /// entry's slot was forcibly recycled under it — retrying against
    /// the same frozen snapshot could never succeed, so the caller
    /// should take a fresh reader if it wants the entry back.
    pub fn lookup_fetch(&self, feature: &[f32], ef: usize,
                        min_similarity: f32,
                        dst: &mut [f32]) -> Option<Lookup> {
        match self.fetch(feature, ef, min_similarity, dst) {
            ReadAttempt::Hit(hit) => Some(hit),
            ReadAttempt::Miss | ReadAttempt::Torn => None,
        }
    }

    /// Lazy whole-batch variant of [`ShardReader::lookup_fetch`] (the
    /// per-row form of [`MemoTier::lookup_fetch_lazy`]).
    pub fn lookup_fetch_lazy(&self, feature: &[f32], ef: usize,
                             min_similarity: f32, buf: &mut Vec<f32>,
                             rows: usize, row: usize) -> Option<Lookup> {
        match self.fetch_lazy(feature, ef, min_similarity, buf, rows, row) {
            ReadAttempt::Hit(hit) => Some(hit),
            ReadAttempt::Miss | ReadAttempt::Torn => None,
        }
    }
}

/// The serve-time attention database shared by all engine replicas.
///
/// ```
/// use attmemo::config::{MemoConfig, ModelConfig};
/// use attmemo::memo::index::HnswParams;
/// use attmemo::memo::MemoTier;
///
/// let cfg = ModelConfig {
///     family: "bert".into(), vocab_size: 64, hidden: 16, layers: 1,
///     heads: 2, ffn: 32, max_len: 8, num_classes: 2, rel_pos_buckets: 4,
///     embed_dim: 4, embed_hidden: 8, embed_segments: 2, causal: false,
/// };
/// let memo = MemoConfig {
///     online_admission: true,
///     max_db_entries: 8,
///     ..MemoConfig::default()
/// };
/// let tier = MemoTier::new(&cfg, 8, HnswParams::default(), &memo);
/// let apm = vec![0.5f32; cfg.apm_elems(8)];
/// let feature: &[f32] = &[1.0, 0.0, 0.0, 0.0];
/// let out = tier
///     .admit_batch(0, &[(feature, apm.as_slice())], 0.9, 16)
///     .unwrap();
/// assert_eq!(out.admitted, 1);
/// let mut fetched = vec![0.0f32; apm.len()];
/// let hit = tier
///     .lookup_fetch(0, &[1.0, 0.0, 0.0, 0.0], 16, 0.9, &mut fetched)
///     .unwrap();
/// assert!(hit.similarity > 0.999);
/// assert_eq!(fetched, apm);
/// ```
pub struct MemoTier {
    shards: Vec<Shard>,
    capacity: usize,
    policy: AdmissionPolicy,
    dedup: bool,
    /// Probe the published snapshot before cloning it, skipping the
    /// publish entirely for all-dedup batches (`MemoConfig::dedup_prepass`).
    prepass: bool,
    seq_len: usize,
    apm_elems: usize,
    embed_dim: usize,
    admissions: AtomicU64,
    evictions: AtomicU64,
    deduped: AtomicU64,
    /// Batches that swapped in a new snapshot.
    publishes: AtomicU64,
    /// Batches served entirely by the dedup prepass (no clone, no swap).
    publish_skips: AtomicU64,
    /// HNSW node records + vector rows deep-copied across all published
    /// snapshots — the O(touched) publish cost the generational index
    /// bounds (flat per batch, independent of index size).
    publish_touched: AtomicU64,
    /// Publishes that found a retire list at/above the high-water mark.
    retire_high_water: AtomicU64,
    /// Retired generations force-reclaimed past the cap.
    forced_reclaims: AtomicU64,
    /// The optional file-backed cold spill tier (`memo/cold.rs`): clock
    /// victims demote into it, hot misses fall through to it.
    cold: Option<Arc<ColdTier>>,
    /// Hot-snapshot misses served from the cold tier.
    cold_hits: AtomicU64,
    /// Cold hits re-admitted into the hot tier.
    promotions: AtomicU64,
    /// Hot clock victims moved into the cold tier (vs dropped).
    demotions: AtomicU64,
    /// Process-unique id keying the thread-local snapshot cache — two
    /// tiers must never share a cache entry even if one is dropped and
    /// the other happens to be allocated at the same address.
    tier_id: u64,
}

/// Source of [`MemoTier::tier_id`] values.
static NEXT_TIER_ID: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Per-thread snapshot cache: `(tier_id, layer) → (publish seq, Arc)`.
    /// A hit whose stored sequence still matches the shard's live counter
    /// serves the snapshot with no lock at all; a mismatch (a publish
    /// happened) falls back to the pointer-swap read lock once and
    /// re-caches. Entries pin their snapshot's `Arc` from this thread —
    /// which is exactly the "stalled reader" shape the retire cap bounds,
    /// so an idle thread can delay reclamation but never unboundedly.
    static SNAP_CACHE: std::cell::RefCell<
        std::collections::HashMap<(u64, usize), (u64, Arc<LayerDb>)>,
    > = std::cell::RefCell::new(std::collections::HashMap::new());
}

/// Cap on per-thread cached snapshots; past it the cache is dropped
/// wholesale (a rare event — it takes hundreds of live tiers × layers on
/// one thread) rather than pinning arbitrarily many snapshot `Arc`s.
const SNAP_CACHE_MAX: usize = 256;

impl MemoTier {
    /// Empty tier with one shard per self-attention layer. Capacity,
    /// admission gating and dedup behaviour come from `memo`
    /// (`max_db_entries`, `online_admission`/`admission_min_attempts`,
    /// `intra_batch_dedup`).
    pub fn new(cfg: &ModelConfig, seq_len: usize, params: HnswParams,
               memo: &MemoConfig) -> Self {
        MemoTier {
            shards: (0..cfg.layers)
                .map(|_| {
                    let mut db = LayerDb::new(cfg, seq_len, params);
                    // Tier shards defer slot reuse: freed pages recycle
                    // only after snapshot quiescence (see module docs).
                    db.set_defer_free(true);
                    db.set_full_index_clone(memo.full_index_clone);
                    let resident = db.arena().resident_bytes();
                    Shard {
                        seq: AtomicU64::new(0),
                        snap: RwLock::new(Arc::new(db)),
                        writer: Mutex::new(ShardWriter::default()),
                        len: AtomicUsize::new(0),
                        resident: AtomicUsize::new(resident),
                    }
                })
                .collect(),
            capacity: memo.max_db_entries,
            policy: AdmissionPolicy::new(
                memo.online_admission, memo.admission_min_attempts),
            dedup: memo.intra_batch_dedup,
            prepass: memo.intra_batch_dedup && memo.dedup_prepass,
            seq_len,
            apm_elems: cfg.apm_elems(seq_len),
            embed_dim: cfg.embed_dim,
            admissions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            deduped: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
            publish_skips: AtomicU64::new(0),
            publish_touched: AtomicU64::new(0),
            retire_high_water: AtomicU64::new(0),
            forced_reclaims: AtomicU64::new(0),
            cold: None,
            cold_hits: AtomicU64::new(0),
            promotions: AtomicU64::new(0),
            demotions: AtomicU64::new(0),
            tier_id: NEXT_TIER_ID.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// [`MemoTier::new`] plus an attached file-backed cold spill tier
    /// rooted at `memo.cold_tier_dir` with a per-layer budget of
    /// `memo.cold_capacity` entries (see the module docs and
    /// `memo/cold.rs`): clock victims demote into it instead of being
    /// dropped, hot misses fall through to it, and cold hits promote
    /// back through the normal admission path. Fallible, unlike
    /// [`MemoTier::new`]: the cold directory is created — and any
    /// previous run's shard files replayed — right here.
    pub fn with_cold_tier(cfg: &ModelConfig, seq_len: usize,
                          params: HnswParams,
                          memo: &MemoConfig) -> Result<MemoTier> {
        let mut tier = MemoTier::new(cfg, seq_len, params, memo);
        tier.attach_cold_tier(memo)?;
        Ok(tier)
    }

    /// Attach a cold spill tier to an already-built tier — the path a
    /// warm-restored tier (`persist::load_warm`) takes, since the warm
    /// loader constructs the tier itself. `memo.cold_tier_dir` must be
    /// set and `memo.cold_capacity` positive; the cold shards take
    /// their dimensions from this tier, so they always match the hot
    /// family. Call before the tier is shared: demotions only consult
    /// the cold tier at admission time, but entries evicted before the
    /// attach are gone, not spilled.
    pub fn attach_cold_tier(&mut self, memo: &MemoConfig) -> Result<()> {
        let dir = memo.cold_tier_dir.as_ref().ok_or_else(|| {
            Error::config("cold tier requires --cold-tier-dir")
        })?;
        self.cold = Some(Arc::new(ColdTier::open(
            dir,
            self.shards.len(),
            self.embed_dim,
            self.apm_elems,
            memo.cold_capacity,
        )?));
        Ok(())
    }

    /// Number of layer shards.
    pub fn num_layers(&self) -> usize {
        self.shards.len()
    }

    /// Per-layer entry budget (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Sequence length the stored APMs were computed at.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// f32 values per stored APM payload.
    pub fn apm_elems(&self) -> usize {
        self.apm_elems
    }

    /// Dimensionality of the embedding feature vectors.
    pub fn embed_dim(&self) -> usize {
        self.embed_dim
    }

    /// The Eq. 3 admission gate shared by every replica.
    pub fn policy(&self) -> &AdmissionPolicy {
        &self.policy
    }

    /// Should a layer invest in admitting this batch's misses? Delegates
    /// to the tier's [`AdmissionPolicy`] with the caller's layer profile
    /// and attempt count.
    pub fn should_admit(&self, profile: Option<&LayerProfile>,
                        attempts: u64, tokens: u64) -> bool {
        self.policy.should_admit(profile, attempts, tokens)
    }

    /// Live entries in one layer shard (atomic gauge, no locks).
    pub fn layer_len(&self, layer: usize) -> usize {
        self.shards[layer].len.load(Ordering::Relaxed)
    }

    /// Whether a layer shard holds no entries (atomic gauge, no locks).
    pub fn is_layer_empty(&self, layer: usize) -> bool {
        self.layer_len(layer) == 0
    }

    /// Total live entries across layers (atomic gauges, no locks).
    pub fn total_entries(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.len.load(Ordering::Relaxed))
            .sum()
    }

    /// Total resident payload bytes across layer arenas (atomic gauges,
    /// no locks).
    pub fn resident_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.resident.load(Ordering::Relaxed))
            .sum()
    }

    /// Total serve-time admissions since creation (all layers).
    pub fn admissions(&self) -> u64 {
        self.admissions.load(Ordering::Relaxed)
    }

    /// Total capacity evictions since creation (all layers).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Total rows skipped by intra-batch dedup since creation.
    pub fn deduped(&self) -> u64 {
        self.deduped.load(Ordering::Relaxed)
    }

    /// Batches that swapped in a new snapshot (admissions, evictions,
    /// restores — everything but the publish-skip fast path).
    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }

    /// Admission batches whose rows all dedup'd against the published
    /// snapshot, skipping the copy-on-write clone and publish entirely
    /// (the cheap-write fast path; see [`MemoTier::admit_batch`]).
    pub fn publish_skips(&self) -> u64 {
        self.publish_skips.load(Ordering::Relaxed)
    }

    /// Total HNSW node records + vector rows deep-copied by published
    /// snapshots since creation — the generational index's O(touched)
    /// publish cost. Per publish this stays flat (proportional to the
    /// batch's fresh rows × graph degree) no matter how large the index
    /// grows; the full-clone bench baseline (`MemoConfig::
    /// full_index_clone`) makes it scale with index size instead.
    pub fn publish_touched_nodes(&self) -> u64 {
        self.publish_touched.load(Ordering::Relaxed)
    }

    /// Publishes that found a shard's retire list at or above the
    /// high-water mark — a stalled reader is delaying snapshot
    /// reclamation (the tier warns once when this first trips).
    pub fn retire_high_water(&self) -> u64 {
        self.retire_high_water.load(Ordering::Relaxed)
    }

    /// Retired snapshot generations force-reclaimed past
    /// [`MemoTier::retire_cap`] (their slots recycled under a potentially
    /// live reader; epoch stamps keep that reader correct).
    pub fn forced_reclaims(&self) -> u64 {
        self.forced_reclaims.load(Ordering::Relaxed)
    }

    /// The attached cold spill tier, if this tier was built through
    /// [`MemoTier::with_cold_tier`].
    pub fn cold(&self) -> Option<&ColdTier> {
        self.cold.as_deref()
    }

    /// Hot-snapshot misses served from the cold tier since creation.
    pub fn cold_hits(&self) -> u64 {
        self.cold_hits.load(Ordering::Relaxed)
    }

    /// Cold hits re-admitted into the hot tier since creation.
    pub fn promotions(&self) -> u64 {
        self.promotions.load(Ordering::Relaxed)
    }

    /// Hot clock victims demoted into the cold tier since creation
    /// (without a cold tier a victim is simply dropped and this stays 0).
    pub fn demotions(&self) -> u64 {
        self.demotions.load(Ordering::Relaxed)
    }

    /// Live entries across the cold tier's shards (0 without one).
    pub fn cold_entries(&self) -> usize {
        self.cold.as_ref().map_or(0, |c| c.total_entries())
    }

    /// Bytes of the cold tier's file-backed payload arenas (0 without
    /// one).
    pub fn cold_resident_bytes(&self) -> usize {
        self.cold.as_ref().map_or(0, |c| c.resident_bytes())
    }

    /// Fraction of all live entries resident in the hot tier — 1.0
    /// without a cold tier (or when both tiers are empty).
    pub fn hot_resident_ratio(&self) -> f64 {
        let hot = self.total_entries();
        let cold = self.cold_entries();
        if hot + cold == 0 {
            1.0
        } else {
            hot as f64 / (hot + cold) as f64
        }
    }

    /// Retired-but-unreclaimed snapshot generations of one layer shard
    /// (diagnostics/tests; takes the shard's writer mutex briefly).
    pub fn retired_generations(&self, layer: usize) -> usize {
        self.shards[layer].writer.lock().unwrap().retired.len()
    }

    /// Hard bound on [`MemoTier::retired_generations`]: publishing past
    /// this force-reclaims the oldest retirees.
    pub fn retire_cap() -> usize {
        RETIRE_CAP
    }

    /// A frozen snapshot of one layer shard. The snapshot `Arc` is served
    /// from this thread's [`SNAP_CACHE`] when the shard's sequence counter
    /// proves no publish happened since it was cached — the steady-state
    /// path, which touches **no mutex or rwlock at all**. Only the first
    /// read after a publish refreshes the cache under the publish cell's
    /// pointer-swap read lock (nanoseconds; the write side holds it only
    /// for the swap itself).
    pub fn reader(&self, layer: usize) -> ShardReader {
        ShardReader {
            db: self.snapshot(layer),
            apm_elems: self.apm_elems,
        }
    }

    /// The current published snapshot, via the seq-validated thread-local
    /// cache (see [`MemoTier::reader`]).
    fn snapshot(&self, layer: usize) -> Arc<LayerDb> {
        let shard = &self.shards[layer];
        let key = (self.tier_id, layer);
        // Fast path: the sequence counter is stable (even) and matches
        // the cached entry — the cached Arc *is* the published snapshot.
        // (`Acquire` pairs with the publisher's post-swap `Release` bump,
        // so everything the snapshot points at is visible.)
        let seq = shard.seq.load(Ordering::Acquire);
        if seq & 1 == 0 {
            let cached = SNAP_CACHE.with(|c| {
                c.borrow().get(&key).and_then(|(s, db)| {
                    (*s == seq).then(|| db.clone())
                })
            });
            if let Some(db) = cached {
                return db;
            }
        }
        // Slow path (first read, or a publish since): take the pointer-
        // swap read lock, then re-validate the sequence. Cache only when
        // no publish raced the clone — a racing publish would otherwise
        // pair the *new* sequence with the *old* snapshot and pin this
        // thread on stale data until the next publish.
        let pre = shard.seq.load(Ordering::Acquire);
        let db = shard.snap.read().unwrap().clone();
        let post = shard.seq.load(Ordering::Acquire);
        if pre == post && post & 1 == 0 {
            SNAP_CACHE.with(|c| {
                let mut c = c.borrow_mut();
                if c.len() >= SNAP_CACHE_MAX {
                    c.clear();
                }
                c.insert(key, (post, db.clone()));
            });
        }
        db
    }

    /// Nearest stored entry for a query, resolved against the snapshot
    /// current at call time. The id/epoch pair is only meaningful within
    /// that snapshot — use [`MemoTier::lookup_fetch`] (or a held
    /// [`ShardReader`]) to atomically obtain the payload.
    pub fn lookup(&self, layer: usize, feature: &[f32],
                  ef: usize) -> Option<Lookup> {
        self.reader(layer).lookup(feature, ef)
    }

    /// Atomic lookup + payload fetch: search for the nearest entry,
    /// reject it if its similarity is below `min_similarity`, otherwise
    /// mark it reused and copy its APM payload into `dst` (which must
    /// hold [`MemoTier::apm_elems`] values).
    ///
    /// This is the seqlock read path: each attempt runs entirely against
    /// one published snapshot (search, epoch-checked read, copy — no lock
    /// held), so a concurrent admission or eviction can never be observed
    /// as a reused slot with stale bytes. If the epoch stamp nevertheless
    /// fails to validate, the shard's sequence counter decides: changed ⇒
    /// retry against the fresh snapshot, unchanged ⇒ genuinely gone.
    ///
    /// With a cold tier attached ([`MemoTier::with_cold_tier`]), a hot
    /// miss falls through to a cold probe; a qualifying cold hit is
    /// served into `dst` and promoted back into the hot tier.
    pub fn lookup_fetch(&self, layer: usize, feature: &[f32], ef: usize,
                        min_similarity: f32,
                        dst: &mut [f32]) -> Option<Lookup> {
        if let Some(hit) = self.seqlock_read(layer, |snap| {
            snap.fetch(feature, ef, min_similarity, dst)
        }) {
            return Some(hit);
        }
        self.cold_fallthrough(layer, feature, ef, min_similarity, dst)
    }

    /// The two-tier miss path: probe the cold tier (if one is attached)
    /// after the hot snapshot missed. A qualifying cold hit is served
    /// from `dst` and *promoted* — the entry leaves the cold shard and
    /// re-enters the hot tier through the ordinary admission path, with
    /// a dedup threshold no similarity can reach so neither the prepass
    /// nor per-row dedup can swallow the row. The returned id/epoch are
    /// resolved against the fresh hot snapshot, keeping the [`Lookup`]
    /// contract identical to a hot hit. Lock order is hot-writer →
    /// cold-shard, never the reverse: `take_nearest` releases the cold
    /// lock before the re-admit takes the hot writer mutex.
    fn cold_fallthrough(&self, layer: usize, feature: &[f32], ef: usize,
                        min_similarity: f32,
                        dst: &mut [f32]) -> Option<Lookup> {
        let cold = self.cold.as_ref()?;
        let promo =
            cold.take_nearest(layer, feature, min_similarity, dst)?;
        self.cold_hits.fetch_add(1, Ordering::Relaxed);
        match self.admit_batch(
            layer,
            &[(promo.feature.as_slice(), &dst[..])],
            2.0,
            ef,
        ) {
            Ok(_) => {
                self.promotions.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => log::warn!(
                "memo tier layer {layer}: promotion re-admit failed \
                 (cold entry served once, then dropped): {e}"
            ),
        }
        match self.lookup(layer, &promo.feature, ef) {
            Some(h) => Some(Lookup {
                id: h.id,
                epoch: h.epoch,
                similarity: promo.similarity,
            }),
            None => {
                // The promoted entry vanished between admit and lookup
                // (racing eviction, or the re-admit failed). Never
                // fabricate an id/epoch — a made-up stamp could
                // validate against an unrelated live entry. Report a
                // clean miss and leave no partial payload behind.
                dst.fill(0.0);
                None
            }
        }
    }

    /// The optimistic reader loop shared by the fetch entry points: run
    /// `attempt` against the current snapshot; on a torn read, retry iff
    /// the shard's sequence counter shows a publish raced the attempt
    /// (unchanged means the entry is genuinely gone).
    fn seqlock_read(&self, layer: usize,
                    mut attempt: impl FnMut(&ShardReader) -> ReadAttempt)
        -> Option<Lookup> {
        let shard = &self.shards[layer];
        loop {
            let seq = shard.seq.load(Ordering::Acquire);
            match attempt(&self.reader(layer)) {
                ReadAttempt::Hit(hit) => return Some(hit),
                ReadAttempt::Miss => return None,
                ReadAttempt::Torn => {
                    if shard.seq.load(Ordering::Acquire) == seq {
                        return None;
                    }
                }
            }
        }
    }

    /// [`MemoTier::lookup_fetch`] into a *lazily allocated* whole-batch
    /// buffer: `buf` holds `rows` rows of [`MemoTier::apm_elems`] values
    /// but may still be empty; it is zero-filled to full size only when
    /// this lookup actually hits, and the payload lands in row `row`.
    ///
    /// This keeps the engine's total-miss fast path allocation-free: a
    /// batch whose rows all miss (the common case on a cold tier) never
    /// pays the multi-MB batch-APM allocation just because an online tier
    /// exists. Same snapshot discipline (and torn-read retry) as
    /// [`MemoTier::lookup_fetch`] — including the cold fallthrough,
    /// which allocates the batch buffer only once a lock-shared cold
    /// *probe* clears the similarity floor, so a two-tier total miss
    /// stays allocation-free too.
    pub fn lookup_fetch_lazy(&self, layer: usize, feature: &[f32],
                             ef: usize, min_similarity: f32,
                             buf: &mut Vec<f32>, rows: usize,
                             row: usize) -> Option<Lookup> {
        if let Some(hit) = self.seqlock_read(layer, |snap| {
            snap.fetch_lazy(feature, ef, min_similarity, buf, rows, row)
        }) {
            return Some(hit);
        }
        let cold = self.cold.as_ref()?;
        cold.probe(layer, feature, min_similarity)?;
        if buf.is_empty() {
            buf.resize(rows * self.apm_elems, 0.0);
        }
        let dst =
            &mut buf[row * self.apm_elems..(row + 1) * self.apm_elems];
        // A racing promoter may have taken the entry since the probe;
        // the fallthrough then misses and the row stays zeroed.
        self.cold_fallthrough(layer, feature, ef, min_similarity, dst)
    }

    /// Start a mutation: clone the published snapshot into a private
    /// working copy. Caller holds the shard's writer mutex. (Quiesced
    /// retirees are reclaimed in [`MemoTier::publish`], not here: a
    /// mutation that errors discards its working copy, and slots released
    /// into a discarded copy would leak from every list for good.)
    fn begin_write(&self, layer: usize) -> LayerDb {
        let cur = self.shards[layer].snap.read().unwrap();
        cur.cow_clone()
    }

    /// Publish a mutated working copy: recycle arena slots whose readers
    /// have all quiesced, refresh the stat gauges, bump the sequence
    /// counter around the pointer swap, and retire the displaced snapshot
    /// together with the slots this mutation freed. Caller holds the
    /// shard's writer mutex.
    fn publish(&self, layer: usize, w: &mut ShardWriter, mut db: LayerDb) {
        // Reclaim in retirement order and stop at the first snapshot that
        // still has readers: a slot freed at epoch k may be referenced by
        // readers of any epoch ≤ k, so nothing younger may recycle first.
        // Running this only on the publish path keeps the retire list
        // intact when a mutation errors out (its discarded working copy
        // must not swallow released slots).
        loop {
            match w.retired.first() {
                Some((snap, _, _)) if Arc::strong_count(snap) == 1 => {}
                _ => break,
            }
            // `strong_count` loads Relaxed; the fence orders the departed
            // readers' payload reads before any future overwrite of the
            // slots we are about to recycle (their Arc drops decremented
            // with Release).
            std::sync::atomic::fence(Ordering::Acquire);
            let (_snap, store, slots) = w.retired.remove(0);
            // Slots belong to the store they were freed on; after a
            // compaction (fresh store) they die with the old store.
            if db.is_on_store(&store) {
                db.release_free_slots(slots);
            }
        }
        // Reclaim bound: one stalled reader must not pin slots without
        // limit. Past the generation cap, force-reclaim the oldest
        // retirees even though a reader may still hold their snapshots —
        // safe because a recycled slot's next `push` bumps the shared
        // tenancy epoch *before* overwriting bytes, so the stalled
        // reader's stamps stop validating (its pre- and post-copy checks
        // turn the fetch into a clean miss, never foreign bytes).
        while w.retired.len() >= RETIRE_CAP {
            let (_snap, store, slots) = w.retired.remove(0);
            if db.is_on_store(&store) {
                db.release_free_slots(slots);
            }
            self.forced_reclaims.fetch_add(1, Ordering::Relaxed);
        }
        let shard = &self.shards[layer];
        // Account the publish's index cost while the working copy is
        // still private: node records + vector rows the mutation actually
        // deep-copied (flat per batch under the generational index).
        self.publish_touched
            .fetch_add(db.index_touched_nodes(), Ordering::Relaxed);
        let freed = db.take_pending_free();
        // The freed slots live on the *publishing* copy's store: an
        // intra-batch compaction drops its pre-compaction pending list
        // with the old arena, so `freed` is always homogeneous on the
        // current store.
        let freed_store = db.store_handle();
        shard.len.store(db.len(), Ordering::Relaxed);
        shard
            .resident
            .store(db.arena().resident_bytes(), Ordering::Relaxed);
        let new = Arc::new(db);
        shard.seq.fetch_add(1, Ordering::AcqRel); // odd: swap in flight
        let old = {
            let mut cell = shard.snap.write().unwrap();
            std::mem::replace(&mut *cell, new)
        };
        shard.seq.fetch_add(1, Ordering::Release); // even: stable
        w.retired.push((old, freed_store, freed));
        self.publishes.fetch_add(1, Ordering::Relaxed);
        if w.retired.len() >= RETIRE_HIGH_WATER
            && self.retire_high_water.fetch_add(1, Ordering::Relaxed) == 0
        {
            log::warn!(
                "memo tier layer {layer}: retire list at high water \
                 ({} generations) — a stalled reader is delaying \
                 snapshot reclamation (forced reclaim past {})",
                w.retired.len(),
                RETIRE_CAP
            );
        }
    }

    /// The dedup-prepass fast path of [`MemoTier::admit_batch`]: probe
    /// every row against the *published* snapshot (the caller holds the
    /// shard's writer mutex, so the snapshot cannot change underneath).
    /// `Some(outcome)` iff every row dedups — the rows' surviving twins
    /// are reuse-marked (lock-free, on the track shared with the live
    /// lineage), the publish-skip counter bumps, and the stat gauges are
    /// refreshed so `STATS` stays live under pure-dedup traffic (the
    /// satellite fix: resident bytes can drift between publishes because
    /// the arena store is shared across snapshots, e.g. after a failed
    /// batch grew it). `None` means at least one row needs admission:
    /// nothing was marked and the caller takes the normal publish path.
    fn prepass_skip(&self, layer: usize, rows: &[(&[f32], &[f32])],
                    dedup_threshold: f32,
                    ef: usize) -> Option<TierAdmitOutcome> {
        let shard = &self.shards[layer];
        let snap = shard.snap.read().unwrap().clone();
        let mut twins = Vec::with_capacity(rows.len());
        for &(feature, _) in rows {
            let hit = snap.lookup(feature, ef)?;
            if hit.similarity < dedup_threshold {
                return None;
            }
            twins.push(hit.id);
        }
        for id in twins {
            snap.mark_reused(id);
        }
        shard.len.store(snap.len(), Ordering::Relaxed);
        shard
            .resident
            .store(snap.arena().resident_bytes(), Ordering::Relaxed);
        self.publish_skips.fetch_add(1, Ordering::Relaxed);
        Some(TierAdmitOutcome {
            admitted: 0,
            evicted: 0,
            deduped: rows.len() as u64,
            demoted: 0,
        })
    }

    /// Admit one batch of miss-path `(feature, apm)` rows into a layer
    /// shard under the shard's writer mutex (readers are never blocked:
    /// they keep serving the previous snapshot until the batch publishes).
    ///
    /// Rows whose nearest stored neighbour already clears
    /// `dedup_threshold` are skipped (and the surviving twin is marked
    /// reused): since earlier rows of the *same call* are visible to later
    /// ones, near-identical rows within one batch admit once — the
    /// intra-batch dedup the ROADMAP called for. At most `capacity` rows
    /// are admitted per call (more would evict entries admitted moments
    /// earlier in the same loop). On error the working copy is discarded
    /// and the published snapshot is left untouched (batches are atomic;
    /// file pages the discarded copy allocated stay orphaned until the
    /// next compaction retires the store — admission errors are
    /// exceptional, so this is bounded in practice).
    ///
    /// **Dedup prepass** (`MemoConfig::dedup_prepass`): before paying the
    /// copy-on-write clone, the batch is probed against the *published*
    /// snapshot; when every row dedups, the whole batch is served by
    /// lock-free reuse marks — no clone, no index insert, no publish.
    /// This is the steady-state shape of warm traffic (affinity routing
    /// makes batches cluster-homogeneous, so repeats arrive together),
    /// where the write path previously paid a full table copy just to
    /// discover there was nothing to write. Mixed batches fall through to
    /// the unchanged path, whose per-row probes run against the working
    /// copy (they must: earlier admissions of the same call are dedup
    /// candidates for later rows).
    pub fn admit_batch(&self, layer: usize, rows: &[(&[f32], &[f32])],
                       dedup_threshold: f32,
                       ef: usize) -> Result<TierAdmitOutcome> {
        let mut w = self.shards[layer].writer.lock().unwrap();
        if self.prepass && !rows.is_empty() {
            if let Some(out) =
                self.prepass_skip(layer, rows, dedup_threshold, ef)
            {
                self.deduped.fetch_add(out.deduped, Ordering::Relaxed);
                return Ok(out);
            }
        }
        let mut db = self.begin_write(layer);
        let quota = if self.capacity == 0 {
            rows.len()
        } else {
            self.capacity.min(rows.len())
        };
        let mut out = TierAdmitOutcome::default();
        for &(feature, apm) in rows {
            if out.admitted as usize >= quota {
                break;
            }
            if self.dedup {
                if let Some(hit) = db.lookup(feature, ef) {
                    if hit.similarity >= dedup_threshold {
                        db.mark_reused(hit.id);
                        out.deduped += 1;
                        continue;
                    }
                }
            }
            let admitted = match self.cold.as_deref() {
                Some(cold) => {
                    // Demote-on-evict: capture each clock victim before
                    // the working copy drops it, then move it into the
                    // cold tier — still under this shard's writer mutex
                    // (the hot-writer → cold-shard lock order; nothing
                    // ever holds them in reverse).
                    let mut demoted: Vec<(Vec<f32>, Vec<f32>)> =
                        Vec::new();
                    let o = db.admit_demoting(
                        feature,
                        apm,
                        self.capacity,
                        &mut |df, da| {
                            demoted.push((df.to_vec(), da.to_vec()));
                        },
                    )?;
                    for (df, da) in demoted {
                        match cold.insert(layer, &df, &da) {
                            Ok(_) => {
                                out.demoted += 1;
                                self.demotions
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                            // Never fail the batch here: the hot-side
                            // eviction already happened in the working
                            // copy, so erroring out would leave the
                            // entry counted in neither tier. Dropping
                            // it is exactly the pre-cold-tier contract.
                            Err(e) => log::warn!(
                                "memo tier layer {layer}: demotion to \
                                 the cold tier failed (entry dropped): \
                                 {e}"
                            ),
                        }
                    }
                    o
                }
                None => db.admit(feature, apm, self.capacity)?,
            };
            out.admitted += 1;
            out.evicted += admitted.evicted.len() as u64;
        }
        self.publish(layer, &mut *w, db);
        self.admissions.fetch_add(out.admitted, Ordering::Relaxed);
        self.evictions.fetch_add(out.evicted, Ordering::Relaxed);
        self.deduped.fetch_add(out.deduped, Ordering::Relaxed);
        Ok(out)
    }

    /// Run `f` against one layer's *snapshot* (persistence, tests,
    /// diagnostics). No lock is held while `f` runs; concurrent
    /// admissions publish new snapshots without waiting for it.
    pub fn read_layer<R>(&self, layer: usize,
                         f: impl FnOnce(&LayerDb) -> R) -> R {
        let snap = { self.shards[layer].snap.read().unwrap().clone() };
        f(&snap)
    }

    /// Like [`MemoTier::read_layer`], but with the shard's *writer*
    /// quiesced for the duration of `f`: admissions/evictions wait,
    /// readers keep serving the published snapshot. Warm snapshots
    /// serialize through this, so a save sees a mutation-stable shard
    /// without ever stalling the lookup path.
    pub fn read_layer_quiesced<R>(&self, layer: usize,
                                  f: impl FnOnce(&LayerDb) -> R) -> R {
        let _w = self.shards[layer].writer.lock().unwrap();
        let snap = { self.shards[layer].snap.read().unwrap().clone() };
        f(&snap)
    }

    /// Run `f` against a writable copy of one layer shard and publish the
    /// result (warm-state restore). Serializes with admissions on the
    /// shard's writer mutex; readers are never blocked.
    ///
    /// The copy is published even when `f` reports a failure through its
    /// return value (this method cannot see into `R`), so `f` must leave
    /// the copy publishable on every path — a caller that errors out of a
    /// multi-step mutation must discard the whole tier (as the warm
    /// loader does) rather than keep serving the partial state.
    pub fn write_layer<R>(&self, layer: usize,
                          f: impl FnOnce(&mut LayerDb) -> R) -> R {
        let mut w = self.shards[layer].writer.lock().unwrap();
        let mut db = self.begin_write(layer);
        let r = f(&mut db);
        self.publish(layer, &mut *w, db);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn cfg(layers: usize) -> ModelConfig {
        ModelConfig {
            family: "bert".into(),
            vocab_size: 256,
            hidden: 32,
            layers,
            heads: 2,
            ffn: 64,
            max_len: 16,
            num_classes: 2,
            rel_pos_buckets: 8,
            embed_dim: 8,
            embed_hidden: 16,
            embed_segments: 4,
            causal: false,
        }
    }

    fn memo(capacity: usize, dedup: bool) -> MemoConfig {
        MemoConfig {
            online_admission: true,
            max_db_entries: capacity,
            admission_min_attempts: 0,
            intra_batch_dedup: dedup,
            ..MemoConfig::default()
        }
    }

    fn unit(rng: &mut Pcg32, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.next_gaussian()).collect();
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    #[test]
    fn near_identical_rows_admit_once() {
        let c = cfg(1);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(32, true));
        let mut rng = Pcg32::seeded(3);
        let base = unit(&mut rng, c.embed_dim);
        let elems = c.apm_elems(16);
        let apm = vec![1.0f32; elems];
        // Eight copies of (almost) the same row in one batch.
        let jittered: Vec<Vec<f32>> = (0..8)
            .map(|_| {
                let mut v: Vec<f32> = base
                    .iter()
                    .map(|&x| x + 0.001 * rng.next_gaussian())
                    .collect();
                let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
                v.iter_mut().for_each(|x| *x /= n);
                v
            })
            .collect();
        let rows: Vec<(&[f32], &[f32])> =
            jittered.iter().map(|f| (f.as_slice(), apm.as_slice())).collect();
        let out = tier.admit_batch(0, &rows, 0.9, 32).unwrap();
        assert_eq!(out.admitted, 1, "duplicates must collapse");
        assert_eq!(out.deduped, 7);
        assert_eq!(tier.layer_len(0), 1);
        assert_eq!(tier.deduped(), 7);
    }

    #[test]
    fn dedup_disabled_admits_every_row() {
        let c = cfg(1);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(32, false));
        let mut rng = Pcg32::seeded(3);
        let base = unit(&mut rng, c.embed_dim);
        let elems = c.apm_elems(16);
        let apm = vec![1.0f32; elems];
        let rows: Vec<(&[f32], &[f32])> =
            (0..4).map(|_| (base.as_slice(), apm.as_slice())).collect();
        let out = tier.admit_batch(0, &rows, 0.9, 32).unwrap();
        assert_eq!(out.admitted, 4);
        assert_eq!(out.deduped, 0);
    }

    #[test]
    fn admission_quota_is_one_capacity_per_batch() {
        let c = cfg(1);
        let cap = 4;
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(cap, false));
        let mut rng = Pcg32::seeded(5);
        let elems = c.apm_elems(16);
        let feats: Vec<Vec<f32>> =
            (0..10).map(|_| unit(&mut rng, c.embed_dim)).collect();
        let apm = vec![0.0f32; elems];
        let rows: Vec<(&[f32], &[f32])> =
            feats.iter().map(|f| (f.as_slice(), apm.as_slice())).collect();
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.admitted as usize, cap);
        assert!(tier.layer_len(0) <= cap);
    }

    /// Satellite regression: admitting a batch of `capacity` fresh rows
    /// into an already-full shard must evict only the pre-existing
    /// entries, never its own same-batch admissions.
    #[test]
    fn full_shard_batch_keeps_its_own_admissions() {
        let c = cfg(1);
        let cap = 8usize;
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(cap, true));
        let mut rng = Pcg32::seeded(31);
        let elems = c.apm_elems(16);
        let apm = vec![0.5f32; elems];
        let old: Vec<Vec<f32>> =
            (0..cap).map(|_| unit(&mut rng, c.embed_dim)).collect();
        let rows: Vec<(&[f32], &[f32])> =
            old.iter().map(|f| (f.as_slice(), apm.as_slice())).collect();
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.admitted as usize, cap);
        assert_eq!(out.evicted, 0, "filling an empty shard evicts nothing");
        assert_eq!(tier.layer_len(0), cap, "shard is now full");

        let fresh: Vec<Vec<f32>> =
            (0..cap).map(|_| unit(&mut rng, c.embed_dim)).collect();
        let rows: Vec<(&[f32], &[f32])> =
            fresh.iter().map(|f| (f.as_slice(), apm.as_slice())).collect();
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.admitted as usize, cap);
        assert_eq!(out.evicted as usize, cap,
                   "exactly the pre-existing entries make room");
        assert_eq!(tier.layer_len(0), cap);
        // Every same-batch admission survived the churn it caused.
        for (k, f) in fresh.iter().enumerate() {
            let hit = tier.lookup(0, f, 32).unwrap();
            assert!(hit.similarity > 0.999,
                    "same-batch admission {k} was evicted by its own batch");
        }
    }

    /// Satellite regression: `deduped` rows must never count against the
    /// per-call admission quota — later fresh rows in the same batch still
    /// get their slots.
    #[test]
    fn deduped_rows_do_not_consume_admission_quota() {
        let c = cfg(1);
        let cap = 4usize;
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(cap, true));
        let mut rng = Pcg32::seeded(37);
        let elems = c.apm_elems(16);
        let apm = vec![1.0f32; elems];
        let base: Vec<Vec<f32>> =
            (0..cap).map(|_| unit(&mut rng, c.embed_dim)).collect();
        // Duplicates interleaved *before* the later fresh rows: if dedup
        // skips consumed quota, the final fresh row would be cut off.
        let order = [0usize, 0, 1, 1, 2, 3];
        let rows: Vec<(&[f32], &[f32])> = order
            .iter()
            .map(|&k| (base[k].as_slice(), apm.as_slice()))
            .collect();
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.admitted as usize, cap,
                   "every distinct row must admit");
        assert_eq!(out.deduped, 2);
        assert_eq!(out.evicted, 0);
        assert_eq!(tier.layer_len(0), cap);
        for f in &base {
            assert!(tier.lookup(0, f, 32).unwrap().similarity > 0.999);
        }
    }

    #[test]
    fn lookup_fetch_lazy_allocates_only_on_hit() {
        let c = cfg(1);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(8, true));
        let mut rng = Pcg32::seeded(41);
        let elems = c.apm_elems(16);
        let f = unit(&mut rng, c.embed_dim);
        let apm: Vec<f32> = (0..elems).map(|i| i as f32).collect();
        let rows = 3usize;

        // Empty tier: misses leave the batch buffer unallocated.
        let mut buf: Vec<f32> = Vec::new();
        assert!(tier
            .lookup_fetch_lazy(0, &f, 32, 0.9, &mut buf, rows, 1)
            .is_none());
        assert!(buf.is_empty(), "a miss must not allocate the batch APM");

        tier.admit_batch(0, &[(f.as_slice(), apm.as_slice())], 0.9, 32)
            .unwrap();
        // Below-floor lookups still don't allocate.
        let far = unit(&mut rng, c.embed_dim);
        assert!(tier
            .lookup_fetch_lazy(0, &far, 32, 1.5, &mut buf, rows, 1)
            .is_none());
        assert!(buf.is_empty(), "a rejected hit must not allocate");
        // First real hit allocates the whole batch buffer and fills its row.
        assert!(tier
            .lookup_fetch_lazy(0, &f, 32, 0.9, &mut buf, rows, 1)
            .is_some());
        assert_eq!(buf.len(), rows * elems);
        assert_eq!(&buf[elems..2 * elems], &apm[..]);
        assert!(buf[..elems].iter().all(|&x| x == 0.0));
        assert!(buf[2 * elems..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn lookup_fetch_respects_similarity_floor() {
        let c = cfg(2);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(8, true));
        let mut rng = Pcg32::seeded(9);
        let f = unit(&mut rng, c.embed_dim);
        let elems = c.apm_elems(16);
        let apm: Vec<f32> = (0..elems).map(|i| i as f32).collect();
        tier.admit_batch(1, &[(f.as_slice(), apm.as_slice())], 0.9, 32)
            .unwrap();
        let mut dst = vec![0.0f32; elems];
        // A floor above the achievable similarity rejects without copying.
        let far = unit(&mut rng, c.embed_dim);
        assert!(tier.lookup_fetch(1, &far, 32, 1.5, &mut dst).is_none());
        assert!(tier.lookup_fetch(1, &f, 32, 0.9, &mut dst).is_some());
        assert_eq!(dst, apm);
        // Layer 0 stayed untouched.
        assert!(tier.is_layer_empty(0));
        assert_eq!(tier.layer_len(1), 1);
    }

    /// Seqlock contract: a `ShardReader` is a frozen view — admissions
    /// published after it was taken are invisible to it, while fresh
    /// readers (and the tier's own methods) see them.
    #[test]
    fn reader_snapshot_is_frozen_across_admissions() {
        let c = cfg(1);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(8, true));
        let mut rng = Pcg32::seeded(47);
        let elems = c.apm_elems(16);
        let apm = vec![1.0f32; elems];
        let fa = unit(&mut rng, c.embed_dim);
        tier.admit_batch(0, &[(fa.as_slice(), apm.as_slice())], 0.99, 32)
            .unwrap();

        let frozen = tier.reader(0);
        assert_eq!(frozen.len(), 1);

        let fb = unit(&mut rng, c.embed_dim);
        tier.admit_batch(0, &[(fb.as_slice(), apm.as_slice())], 0.99, 32)
            .unwrap();

        // The frozen reader still serves the old epoch…
        assert_eq!(frozen.len(), 1, "snapshot grew under a frozen reader");
        assert!(frozen.lookup(&fb, 32).map_or(true,
                                              |h| h.similarity < 0.999),
                "snapshot must not see the later admission");
        let mut dst = vec![0.0f32; elems];
        assert!(frozen.lookup_fetch(&fa, 32, 0.9, &mut dst).is_some(),
                "pre-snapshot entries keep serving");
        // …while the tier (fresh snapshot) sees both entries.
        assert_eq!(tier.layer_len(0), 2);
        assert!(tier.lookup_fetch(0, &fb, 32, 0.9, &mut dst).is_some());
    }

    /// Batch atomicity: an admission that errors mid-batch discards the
    /// working copy — the published snapshot and the gauges are untouched.
    #[test]
    fn failed_admit_batch_discards_partial_mutation() {
        let c = cfg(1);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(8, false));
        let mut rng = Pcg32::seeded(53);
        let f0 = unit(&mut rng, c.embed_dim);
        let f1 = unit(&mut rng, c.embed_dim);
        let good = vec![0.0f32; c.apm_elems(16)];
        let bad = vec![0.0f32; 3]; // wrong payload size ⇒ arena error
        let rows: Vec<(&[f32], &[f32])> = vec![
            (f0.as_slice(), good.as_slice()),
            (f1.as_slice(), bad.as_slice()),
        ];
        assert!(tier.admit_batch(0, &rows, 2.0, 32).is_err());
        assert_eq!(tier.layer_len(0), 0, "failed batch must not publish");
        assert_eq!(tier.admissions(), 0);
        assert!(tier.lookup(0, &f0, 32).is_none());
        // The shard still works afterwards.
        let rows: Vec<(&[f32], &[f32])> =
            vec![(f0.as_slice(), good.as_slice())];
        tier.admit_batch(0, &rows, 2.0, 32).unwrap();
        assert_eq!(tier.layer_len(0), 1);
    }

    /// Cheap-write fast path: a batch whose rows all dedup against the
    /// published snapshot must skip the copy-on-write publish entirely —
    /// and still mark its twins reused on the shared (lock-free) track.
    #[test]
    fn all_dedup_batch_skips_publish() {
        let c = cfg(1);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(32, true));
        let mut rng = Pcg32::seeded(61);
        let elems = c.apm_elems(16);
        let apm = vec![1.0f32; elems];
        let feats: Vec<Vec<f32>> =
            (0..4).map(|_| unit(&mut rng, c.embed_dim)).collect();
        let rows: Vec<(&[f32], &[f32])> =
            feats.iter().map(|f| (f.as_slice(), apm.as_slice())).collect();

        // Cold tier: the first batch cannot skip (rows are misses).
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.admitted, 4);
        assert_eq!(tier.publishes(), 1);
        assert_eq!(tier.publish_skips(), 0);

        // Steady state: the identical batch dedups wholesale — no new
        // publish, every row counted as deduped, reuse marks landed.
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.admitted, 0);
        assert_eq!(out.deduped, 4);
        assert_eq!(tier.publishes(), 1, "all-dedup batch must not publish");
        assert_eq!(tier.publish_skips(), 1);
        assert_eq!(tier.deduped(), 4);
        assert_eq!(tier.layer_len(0), 4);
        tier.read_layer(0, |layer| {
            assert_eq!(layer.reuse_counts(), vec![1, 1, 1, 1],
                       "prepass must mark the surviving twins reused");
        });

        // A single fresh row forces the whole batch down the publish
        // path — and nothing was double-marked by the abandoned prepass.
        let fresh = unit(&mut rng, c.embed_dim);
        let mut mixed = rows.clone();
        mixed.push((fresh.as_slice(), apm.as_slice()));
        let out = tier.admit_batch(0, &mixed, 0.99, 32).unwrap();
        assert_eq!(out.admitted, 1);
        assert_eq!(out.deduped, 4);
        assert_eq!(tier.publishes(), 2, "mixed batch must publish");
        assert_eq!(tier.publish_skips(), 1);
        tier.read_layer(0, |layer| {
            assert_eq!(layer.reuse_counts()[..4], [2, 2, 2, 2],
                       "per-row dedup marks exactly once per twin");
        });
    }

    /// `dedup_prepass: false` forces every batch through the full
    /// copy-on-write publish path (the A/B baseline), with identical
    /// dedup outcomes.
    #[test]
    fn prepass_disabled_publishes_every_batch() {
        let c = cfg(1);
        let mut m = memo(32, true);
        m.dedup_prepass = false;
        let tier = MemoTier::new(&c, 16, HnswParams::default(), &m);
        let mut rng = Pcg32::seeded(67);
        let elems = c.apm_elems(16);
        let apm = vec![1.0f32; elems];
        let feats: Vec<Vec<f32>> =
            (0..4).map(|_| unit(&mut rng, c.embed_dim)).collect();
        let rows: Vec<(&[f32], &[f32])> =
            feats.iter().map(|f| (f.as_slice(), apm.as_slice())).collect();
        tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.deduped, 4, "dedup itself is unaffected");
        assert_eq!(tier.publishes(), 2);
        assert_eq!(tier.publish_skips(), 0);
    }

    /// Reclaim bound: a reader pinning one old snapshot while batches
    /// churn must not grow the retire list past the cap — the high-water
    /// counter trips, forced reclaims kick in, and the pinned reader
    /// keeps resolving its own view (or cleanly missing), never foreign
    /// bytes (covered in depth by `tests/memo_tier.rs`).
    #[test]
    fn retire_list_is_bounded_under_a_stalled_reader() {
        let c = cfg(1);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(4, false));
        let mut rng = Pcg32::seeded(71);
        let elems = c.apm_elems(16);
        let apm = vec![0.0f32; elems];
        let f = unit(&mut rng, c.embed_dim);
        tier.admit_batch(0, &[(f.as_slice(), apm.as_slice())], 2.0, 32)
            .unwrap();
        let stalled = tier.reader(0);

        for _ in 0..4 * MemoTier::retire_cap() {
            let g = unit(&mut rng, c.embed_dim);
            tier.admit_batch(0, &[(g.as_slice(), apm.as_slice())], 2.0, 32)
                .unwrap();
            assert!(tier.retired_generations(0) <= MemoTier::retire_cap(),
                    "retire list exceeded the generation cap");
        }
        assert!(tier.retire_high_water() > 0,
                "the high-water warning counter must trip");
        assert!(tier.forced_reclaims() > 0,
                "churn past the cap must force-reclaim");
        assert!(!stalled.is_empty(), "the pinned snapshot view is frozen");
        drop(stalled);

        // Once the stalled reader departs, later publishes drain the
        // backlog the normal (quiesced) way.
        for _ in 0..MemoTier::retire_cap() {
            let g = unit(&mut rng, c.embed_dim);
            tier.admit_batch(0, &[(g.as_slice(), apm.as_slice())], 2.0, 32)
                .unwrap();
        }
        assert!(tier.retired_generations(0) <= 1,
                "backlog must drain after the reader departs");
    }

    /// The lock-free stat gauges track publishes.
    #[test]
    fn stat_gauges_follow_publishes() {
        let c = cfg(2);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(16, false));
        assert_eq!(tier.total_entries(), 0);
        assert!(tier.resident_bytes() > 0, "arenas preallocate pages");
        let mut rng = Pcg32::seeded(59);
        let elems = c.apm_elems(16);
        let apm = vec![0.0f32; elems];
        for li in 0..2 {
            for _ in 0..3 {
                let f = unit(&mut rng, c.embed_dim);
                tier.admit_batch(li, &[(f.as_slice(), apm.as_slice())],
                                 2.0, 32)
                    .unwrap();
            }
        }
        assert_eq!(tier.layer_len(0), 3);
        assert_eq!(tier.layer_len(1), 3);
        assert_eq!(tier.total_entries(), 6);
        assert!(!tier.is_layer_empty(0));
    }

    fn cold_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn cold_memo(capacity: usize, cold_cap: usize,
                 dir: &std::path::Path) -> MemoConfig {
        MemoConfig {
            cold_tier_dir: Some(dir.to_path_buf()),
            cold_capacity: cold_cap,
            ..memo(capacity, false)
        }
    }

    /// The tentpole contract end to end: clock victims demote into the
    /// cold tier instead of vanishing, a hot miss falls through to a
    /// cold hit with the original payload, and the hit promotes the
    /// entry back into the hot tier (demoting a fresh victim in turn).
    #[test]
    fn demote_on_evict_spills_and_promotes() {
        let c = cfg(1);
        let d = cold_dir("attmemo_tier_cold_promote");
        let tier = MemoTier::with_cold_tier(
            &c, 16, HnswParams::default(), &cold_memo(2, 8, &d))
            .unwrap();
        let mut rng = Pcg32::seeded(83);
        let elems = c.apm_elems(16);
        let feats: Vec<Vec<f32>> =
            (0..4).map(|_| unit(&mut rng, c.embed_dim)).collect();
        for (k, f) in feats.iter().enumerate() {
            let apm = vec![(10 + k) as f32; elems];
            tier.admit_batch(0, &[(f.as_slice(), apm.as_slice())],
                             2.0, 32)
                .unwrap();
        }
        assert_eq!(tier.layer_len(0), 2, "hot budget enforced");
        assert_eq!(tier.cold_entries(), 2, "victims demoted, not dropped");
        assert_eq!(tier.demotions(), 2);
        assert_eq!(tier.evictions(), 2, "eviction count is unchanged");
        assert!((tier.hot_resident_ratio() - 0.5).abs() < 1e-9);
        assert!(tier.cold_resident_bytes() > 0);

        // The first admitted feature was clock-demoted: a hot lookup
        // misses, the two-tier fetch serves it from cold and promotes.
        let mut dst = vec![0.0f32; elems];
        let hit = tier
            .lookup_fetch(0, &feats[0], 32, 0.9, &mut dst)
            .expect("cold fallthrough must serve the demoted entry");
        assert!(hit.similarity > 0.999);
        assert_eq!(dst, vec![10.0f32; elems],
                   "the original payload tag survives the round trip");
        assert_eq!(tier.cold_hits(), 1);
        assert_eq!(tier.promotions(), 1);
        assert_eq!(tier.layer_len(0), 2, "promotion respects the budget");
        assert_eq!(tier.cold_entries(), 2,
                   "promotion's own eviction demotes a fresh victim");
        assert_eq!(tier.demotions(), 3);

        // Now resident in the hot tier: the next fetch is a hot hit.
        let hot = tier
            .lookup_fetch(0, &feats[0], 32, 0.9, &mut dst)
            .expect("promoted entry must be hot now");
        assert!(hot.similarity > 0.999);
        assert_eq!(tier.cold_hits(), 1, "second fetch never went cold");
    }

    /// The lazy two-tier path: a cold *miss* leaves the batch buffer
    /// unallocated; a cold hit allocates it, fills exactly the row, and
    /// promotes like the eager path.
    #[test]
    fn lazy_fetch_allocates_only_on_cold_hit() {
        let c = cfg(1);
        let d = cold_dir("attmemo_tier_cold_lazy");
        let tier = MemoTier::with_cold_tier(
            &c, 16, HnswParams::default(), &cold_memo(1, 8, &d))
            .unwrap();
        let mut rng = Pcg32::seeded(89);
        let elems = c.apm_elems(16);
        let feats: Vec<Vec<f32>> =
            (0..2).map(|_| unit(&mut rng, c.embed_dim)).collect();
        for (k, f) in feats.iter().enumerate() {
            let apm = vec![(10 + k) as f32; elems];
            tier.admit_batch(0, &[(f.as_slice(), apm.as_slice())],
                             2.0, 32)
                .unwrap();
        }
        // feats[0] was demoted; an unrelated probe misses both tiers.
        let probe = unit(&mut rng, c.embed_dim);
        let mut buf = Vec::new();
        assert!(tier
            .lookup_fetch_lazy(0, &probe, 32, 0.9, &mut buf, 2, 0)
            .is_none());
        assert!(buf.is_empty(),
                "a two-tier total miss must stay allocation-free");
        let hit = tier
            .lookup_fetch_lazy(0, &feats[0], 32, 0.9, &mut buf, 2, 1)
            .expect("cold hit through the lazy path");
        assert!(hit.similarity > 0.999);
        assert_eq!(buf.len(), 2 * elems);
        assert_eq!(&buf[elems..], vec![10.0f32; elems].as_slice(),
                   "the cold payload lands in the requested row");
        assert_eq!(&buf[..elems], vec![0.0f32; elems].as_slice(),
                   "other rows stay zeroed");
        assert_eq!(tier.promotions(), 1);
    }

    /// Demoted entries survive a restart: reopening the cold directory
    /// replays the shard files and the two-tier fetch serves the
    /// original payloads into a fresh (empty) hot tier.
    #[test]
    fn cold_tier_survives_restart() {
        let c = cfg(1);
        let d = cold_dir("attmemo_tier_cold_restart");
        let elems = c.apm_elems(16);
        let mut rng = Pcg32::seeded(97);
        let feats: Vec<Vec<f32>> =
            (0..3).map(|_| unit(&mut rng, c.embed_dim)).collect();
        {
            let tier = MemoTier::with_cold_tier(
                &c, 16, HnswParams::default(), &cold_memo(1, 8, &d))
                .unwrap();
            for (k, f) in feats.iter().enumerate() {
                let apm = vec![(10 + k) as f32; elems];
                tier.admit_batch(0, &[(f.as_slice(), apm.as_slice())],
                                 2.0, 32)
                    .unwrap();
            }
            assert_eq!(tier.cold_entries(), 2);
        }
        let tier = MemoTier::with_cold_tier(
            &c, 16, HnswParams::default(), &cold_memo(1, 8, &d))
            .unwrap();
        assert_eq!(tier.total_entries(), 0, "hot tier restarts empty");
        assert_eq!(tier.cold_entries(), 2,
                   "demoted entries survive the restart");
        let mut dst = vec![0.0f32; elems];
        tier.lookup_fetch(0, &feats[1], 32, 0.9, &mut dst)
            .expect("recovered cold entry must be servable");
        assert_eq!(dst, vec![11.0f32; elems],
                   "payload tag intact across the restart");
    }

    /// Configuration errors surface at construction, not first use.
    #[test]
    fn with_cold_tier_rejects_bad_config() {
        let c = cfg(1);
        let err = MemoTier::with_cold_tier(
            &c, 16, HnswParams::default(), &memo(2, false))
            .unwrap_err();
        assert!(format!("{err}").contains("--cold-tier-dir"), "{err}");
        let d = cold_dir("attmemo_tier_cold_badcfg");
        let err = MemoTier::with_cold_tier(
            &c, 16, HnswParams::default(), &cold_memo(2, 0, &d))
            .unwrap_err();
        assert!(format!("{err}").contains("--cold-capacity"), "{err}");
    }
}
