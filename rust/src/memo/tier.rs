//! The shared online memoization tier: per-layer sharded, concurrently
//! readable, writable at serve time.
//!
//! PR 1's online overlay lived inside the engine behind one
//! `Arc<Mutex<Engine>>`, so every lookup and admission serialized on a
//! single lock and the warmed state died with the process. [`MemoTier`]
//! extracts that overlay into a standalone subsystem shaped like the
//! paper's big-memory attention database:
//!
//! * **Per-layer shards** — one [`LayerDb`] per self-attention layer, each
//!   behind its own `RwLock`. The request path is read-mostly (lookups +
//!   payload fetches take a shard *read* lock, so any number of engine
//!   replicas search the same layer in parallel); only admission and
//!   eviction take the *write* lock, and only for their own layer.
//! * **Shared ownership** — the tier is `Sync` and meant to be shared as
//!   `Arc<MemoTier>` across engine replicas (`serving::Server` runs one
//!   batcher thread per replica against one tier), so a miss warmed by one
//!   replica is a hit for every other.
//! * **Race-free fetches** — [`MemoTier::lookup_fetch`] performs the index
//!   search, reuse marking and payload copy under a single read lock, and
//!   the payload read is epoch-checked (`ApmArena::get_checked`), so a
//!   concurrent eviction in the same shard can never be observed as a
//!   reused slot with stale bytes.
//! * **Intra-batch dedup** — [`MemoTier::admit_batch`] admits a batch of
//!   miss-path rows under one write lock, skipping rows whose nearest
//!   neighbour (including rows admitted earlier in the *same batch*)
//!   already clears the similarity threshold, so near-identical rows admit
//!   once instead of flooding the capacity budget with duplicates.
//!
//! Warm state survives restarts through `memo::persist::{save_warm,
//! load_warm}` (see `docs/PERSISTENCE.md` for the file format).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::config::{MemoConfig, ModelConfig};
use crate::memo::attdb::{LayerDb, Lookup};
use crate::memo::index::HnswParams;
use crate::memo::policy::{AdmissionPolicy, LayerProfile};
use crate::Result;

/// What one batched admission did (per layer shard).
#[derive(Debug, Clone, Copy, Default)]
pub struct TierAdmitOutcome {
    /// Rows stored in the shard.
    pub admitted: u64,
    /// Entries evicted by the capacity budget to make room.
    pub evicted: u64,
    /// Rows skipped because a near-identical entry (often from the same
    /// batch) was already stored.
    pub deduped: u64,
}

/// The serve-time attention database shared by all engine replicas.
///
/// ```
/// use attmemo::config::{MemoConfig, ModelConfig};
/// use attmemo::memo::index::HnswParams;
/// use attmemo::memo::MemoTier;
///
/// let cfg = ModelConfig {
///     family: "bert".into(), vocab_size: 64, hidden: 16, layers: 1,
///     heads: 2, ffn: 32, max_len: 8, num_classes: 2, rel_pos_buckets: 4,
///     embed_dim: 4, embed_hidden: 8, embed_segments: 2, causal: false,
/// };
/// let memo = MemoConfig {
///     online_admission: true,
///     max_db_entries: 8,
///     ..MemoConfig::default()
/// };
/// let tier = MemoTier::new(&cfg, 8, HnswParams::default(), &memo);
/// let apm = vec![0.5f32; cfg.apm_elems(8)];
/// let feature: &[f32] = &[1.0, 0.0, 0.0, 0.0];
/// let out = tier
///     .admit_batch(0, &[(feature, apm.as_slice())], 0.9, 16)
///     .unwrap();
/// assert_eq!(out.admitted, 1);
/// let mut fetched = vec![0.0f32; apm.len()];
/// let hit = tier
///     .lookup_fetch(0, &[1.0, 0.0, 0.0, 0.0], 16, 0.9, &mut fetched)
///     .unwrap();
/// assert!(hit.similarity > 0.999);
/// assert_eq!(fetched, apm);
/// ```
pub struct MemoTier {
    shards: Vec<RwLock<LayerDb>>,
    capacity: usize,
    policy: AdmissionPolicy,
    dedup: bool,
    seq_len: usize,
    apm_elems: usize,
    embed_dim: usize,
    admissions: AtomicU64,
    evictions: AtomicU64,
    deduped: AtomicU64,
}

impl MemoTier {
    /// Empty tier with one shard per self-attention layer. Capacity,
    /// admission gating and dedup behaviour come from `memo`
    /// (`max_db_entries`, `online_admission`/`admission_min_attempts`,
    /// `intra_batch_dedup`).
    pub fn new(cfg: &ModelConfig, seq_len: usize, params: HnswParams,
               memo: &MemoConfig) -> Self {
        MemoTier {
            shards: (0..cfg.layers)
                .map(|_| RwLock::new(LayerDb::new(cfg, seq_len, params)))
                .collect(),
            capacity: memo.max_db_entries,
            policy: AdmissionPolicy::new(
                memo.online_admission, memo.admission_min_attempts),
            dedup: memo.intra_batch_dedup,
            seq_len,
            apm_elems: cfg.apm_elems(seq_len),
            embed_dim: cfg.embed_dim,
            admissions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            deduped: AtomicU64::new(0),
        }
    }

    /// Number of layer shards.
    pub fn num_layers(&self) -> usize {
        self.shards.len()
    }

    /// Per-layer entry budget (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Sequence length the stored APMs were computed at.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// f32 values per stored APM payload.
    pub fn apm_elems(&self) -> usize {
        self.apm_elems
    }

    /// Dimensionality of the embedding feature vectors.
    pub fn embed_dim(&self) -> usize {
        self.embed_dim
    }

    /// The Eq. 3 admission gate shared by every replica.
    pub fn policy(&self) -> &AdmissionPolicy {
        &self.policy
    }

    /// Should a layer invest in admitting this batch's misses? Delegates
    /// to the tier's [`AdmissionPolicy`] with the caller's layer profile
    /// and attempt count.
    pub fn should_admit(&self, profile: Option<&LayerProfile>,
                        attempts: u64, tokens: u64) -> bool {
        self.policy.should_admit(profile, attempts, tokens)
    }

    /// Live entries in one layer shard.
    pub fn layer_len(&self, layer: usize) -> usize {
        self.shards[layer].read().unwrap().len()
    }

    /// Whether a layer shard holds no entries.
    pub fn is_layer_empty(&self, layer: usize) -> bool {
        self.shards[layer].read().unwrap().is_empty()
    }

    /// Total live entries across layers.
    pub fn total_entries(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// Total resident payload bytes across layer arenas.
    pub fn resident_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().arena().resident_bytes())
            .sum()
    }

    /// Total serve-time admissions since creation (all layers).
    pub fn admissions(&self) -> u64 {
        self.admissions.load(Ordering::Relaxed)
    }

    /// Total capacity evictions since creation (all layers).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Total rows skipped by intra-batch dedup since creation.
    pub fn deduped(&self) -> u64 {
        self.deduped.load(Ordering::Relaxed)
    }

    /// Nearest stored entry for a query (shard read lock; runs in
    /// parallel with other lookups). The returned id is only guaranteed
    /// stable while no admission runs — use [`MemoTier::lookup_fetch`] to
    /// atomically obtain the payload.
    pub fn lookup(&self, layer: usize, feature: &[f32],
                  ef: usize) -> Option<Lookup> {
        self.shards[layer].read().unwrap().lookup(feature, ef)
    }

    /// Atomic lookup + payload fetch: under one shard read lock, search
    /// for the nearest entry, reject it if its similarity is below
    /// `min_similarity`, otherwise mark it reused and copy its APM payload
    /// into `dst` (which must hold [`MemoTier::apm_elems`] values).
    ///
    /// Because search, epoch-checked read and copy share the lock, a
    /// concurrent admission/eviction in the same shard can never be
    /// observed as a reused arena slot with stale bytes.
    pub fn lookup_fetch(&self, layer: usize, feature: &[f32], ef: usize,
                        min_similarity: f32,
                        dst: &mut [f32]) -> Option<Lookup> {
        let shard = self.shards[layer].read().unwrap();
        let hit = shard.lookup(feature, ef)?;
        if hit.similarity < min_similarity {
            return None;
        }
        let apm = shard.arena().get_checked(hit.id, hit.epoch).ok()?;
        dst.copy_from_slice(apm);
        shard.mark_reused(hit.id);
        Some(hit)
    }

    /// [`MemoTier::lookup_fetch`] into a *lazily allocated* whole-batch
    /// buffer: `buf` holds `rows` rows of [`MemoTier::apm_elems`] values
    /// but may still be empty; it is zero-filled to full size only when
    /// this lookup actually hits, and the payload lands in row `row`.
    ///
    /// This keeps the engine's total-miss fast path allocation-free: a
    /// batch whose rows all miss (the common case on a cold tier) never
    /// pays the multi-MB batch-APM allocation just because an online tier
    /// exists. Same atomicity as `lookup_fetch` — search, epoch-checked
    /// read, copy and reuse-mark all run under one shard read lock.
    pub fn lookup_fetch_lazy(&self, layer: usize, feature: &[f32],
                             ef: usize, min_similarity: f32,
                             buf: &mut Vec<f32>, rows: usize,
                             row: usize) -> Option<Lookup> {
        let shard = self.shards[layer].read().unwrap();
        let hit = shard.lookup(feature, ef)?;
        if hit.similarity < min_similarity {
            return None;
        }
        let apm = shard.arena().get_checked(hit.id, hit.epoch).ok()?;
        if buf.is_empty() {
            buf.resize(rows * self.apm_elems, 0.0);
        }
        buf[row * self.apm_elems..(row + 1) * self.apm_elems]
            .copy_from_slice(apm);
        shard.mark_reused(hit.id);
        Some(hit)
    }

    /// Admit one batch of miss-path `(feature, apm)` rows into a layer
    /// shard under a single write lock.
    ///
    /// Rows whose nearest stored neighbour already clears
    /// `dedup_threshold` are skipped (and the surviving twin is marked
    /// reused): since earlier rows of the *same call* are visible to later
    /// ones, near-identical rows within one batch admit once — the
    /// intra-batch dedup the ROADMAP called for. At most `capacity` rows
    /// are admitted per call (more would evict entries admitted moments
    /// earlier in the same loop).
    pub fn admit_batch(&self, layer: usize, rows: &[(&[f32], &[f32])],
                       dedup_threshold: f32,
                       ef: usize) -> Result<TierAdmitOutcome> {
        let mut shard = self.shards[layer].write().unwrap();
        let quota = if self.capacity == 0 {
            rows.len()
        } else {
            self.capacity.min(rows.len())
        };
        let mut out = TierAdmitOutcome::default();
        for &(feature, apm) in rows {
            if out.admitted as usize >= quota {
                break;
            }
            if self.dedup {
                if let Some(hit) = shard.lookup(feature, ef) {
                    if hit.similarity >= dedup_threshold {
                        shard.mark_reused(hit.id);
                        out.deduped += 1;
                        continue;
                    }
                }
            }
            let admitted = shard.admit(feature, apm, self.capacity)?;
            out.admitted += 1;
            out.evicted += admitted.evicted.len() as u64;
        }
        self.admissions.fetch_add(out.admitted, Ordering::Relaxed);
        self.evictions.fetch_add(out.evicted, Ordering::Relaxed);
        self.deduped.fetch_add(out.deduped, Ordering::Relaxed);
        Ok(out)
    }

    /// Run `f` against one layer shard under the read lock (persistence,
    /// tests, diagnostics).
    pub fn read_layer<R>(&self, layer: usize,
                         f: impl FnOnce(&LayerDb) -> R) -> R {
        f(&self.shards[layer].read().unwrap())
    }

    /// Run `f` against one layer shard under the write lock (warm-state
    /// restore).
    pub fn write_layer<R>(&self, layer: usize,
                          f: impl FnOnce(&mut LayerDb) -> R) -> R {
        f(&mut self.shards[layer].write().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn cfg(layers: usize) -> ModelConfig {
        ModelConfig {
            family: "bert".into(),
            vocab_size: 256,
            hidden: 32,
            layers,
            heads: 2,
            ffn: 64,
            max_len: 16,
            num_classes: 2,
            rel_pos_buckets: 8,
            embed_dim: 8,
            embed_hidden: 16,
            embed_segments: 4,
            causal: false,
        }
    }

    fn memo(capacity: usize, dedup: bool) -> MemoConfig {
        MemoConfig {
            online_admission: true,
            max_db_entries: capacity,
            admission_min_attempts: 0,
            intra_batch_dedup: dedup,
            ..MemoConfig::default()
        }
    }

    fn unit(rng: &mut Pcg32, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.next_gaussian()).collect();
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    #[test]
    fn near_identical_rows_admit_once() {
        let c = cfg(1);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(32, true));
        let mut rng = Pcg32::seeded(3);
        let base = unit(&mut rng, c.embed_dim);
        let elems = c.apm_elems(16);
        let apm = vec![1.0f32; elems];
        // Eight copies of (almost) the same row in one batch.
        let jittered: Vec<Vec<f32>> = (0..8)
            .map(|_| {
                let mut v: Vec<f32> = base
                    .iter()
                    .map(|&x| x + 0.001 * rng.next_gaussian())
                    .collect();
                let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
                v.iter_mut().for_each(|x| *x /= n);
                v
            })
            .collect();
        let rows: Vec<(&[f32], &[f32])> =
            jittered.iter().map(|f| (f.as_slice(), apm.as_slice())).collect();
        let out = tier.admit_batch(0, &rows, 0.9, 32).unwrap();
        assert_eq!(out.admitted, 1, "duplicates must collapse");
        assert_eq!(out.deduped, 7);
        assert_eq!(tier.layer_len(0), 1);
        assert_eq!(tier.deduped(), 7);
    }

    #[test]
    fn dedup_disabled_admits_every_row() {
        let c = cfg(1);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(32, false));
        let mut rng = Pcg32::seeded(3);
        let base = unit(&mut rng, c.embed_dim);
        let elems = c.apm_elems(16);
        let apm = vec![1.0f32; elems];
        let rows: Vec<(&[f32], &[f32])> =
            (0..4).map(|_| (base.as_slice(), apm.as_slice())).collect();
        let out = tier.admit_batch(0, &rows, 0.9, 32).unwrap();
        assert_eq!(out.admitted, 4);
        assert_eq!(out.deduped, 0);
    }

    #[test]
    fn admission_quota_is_one_capacity_per_batch() {
        let c = cfg(1);
        let cap = 4;
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(cap, false));
        let mut rng = Pcg32::seeded(5);
        let elems = c.apm_elems(16);
        let feats: Vec<Vec<f32>> =
            (0..10).map(|_| unit(&mut rng, c.embed_dim)).collect();
        let apm = vec![0.0f32; elems];
        let rows: Vec<(&[f32], &[f32])> =
            feats.iter().map(|f| (f.as_slice(), apm.as_slice())).collect();
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.admitted as usize, cap);
        assert!(tier.layer_len(0) <= cap);
    }

    /// Satellite regression: admitting a batch of `capacity` fresh rows
    /// into an already-full shard must evict only the pre-existing
    /// entries, never its own same-batch admissions.
    #[test]
    fn full_shard_batch_keeps_its_own_admissions() {
        let c = cfg(1);
        let cap = 8usize;
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(cap, true));
        let mut rng = Pcg32::seeded(31);
        let elems = c.apm_elems(16);
        let apm = vec![0.5f32; elems];
        let old: Vec<Vec<f32>> =
            (0..cap).map(|_| unit(&mut rng, c.embed_dim)).collect();
        let rows: Vec<(&[f32], &[f32])> =
            old.iter().map(|f| (f.as_slice(), apm.as_slice())).collect();
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.admitted as usize, cap);
        assert_eq!(out.evicted, 0, "filling an empty shard evicts nothing");
        assert_eq!(tier.layer_len(0), cap, "shard is now full");

        let fresh: Vec<Vec<f32>> =
            (0..cap).map(|_| unit(&mut rng, c.embed_dim)).collect();
        let rows: Vec<(&[f32], &[f32])> =
            fresh.iter().map(|f| (f.as_slice(), apm.as_slice())).collect();
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.admitted as usize, cap);
        assert_eq!(out.evicted as usize, cap,
                   "exactly the pre-existing entries make room");
        assert_eq!(tier.layer_len(0), cap);
        // Every same-batch admission survived the churn it caused.
        for (k, f) in fresh.iter().enumerate() {
            let hit = tier.lookup(0, f, 32).unwrap();
            assert!(hit.similarity > 0.999,
                    "same-batch admission {k} was evicted by its own batch");
        }
    }

    /// Satellite regression: `deduped` rows must never count against the
    /// per-call admission quota — later fresh rows in the same batch still
    /// get their slots.
    #[test]
    fn deduped_rows_do_not_consume_admission_quota() {
        let c = cfg(1);
        let cap = 4usize;
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(cap, true));
        let mut rng = Pcg32::seeded(37);
        let elems = c.apm_elems(16);
        let apm = vec![1.0f32; elems];
        let base: Vec<Vec<f32>> =
            (0..cap).map(|_| unit(&mut rng, c.embed_dim)).collect();
        // Duplicates interleaved *before* the later fresh rows: if dedup
        // skips consumed quota, the final fresh row would be cut off.
        let order = [0usize, 0, 1, 1, 2, 3];
        let rows: Vec<(&[f32], &[f32])> = order
            .iter()
            .map(|&k| (base[k].as_slice(), apm.as_slice()))
            .collect();
        let out = tier.admit_batch(0, &rows, 0.99, 32).unwrap();
        assert_eq!(out.admitted as usize, cap,
                   "every distinct row must admit");
        assert_eq!(out.deduped, 2);
        assert_eq!(out.evicted, 0);
        assert_eq!(tier.layer_len(0), cap);
        for f in &base {
            assert!(tier.lookup(0, f, 32).unwrap().similarity > 0.999);
        }
    }

    #[test]
    fn lookup_fetch_lazy_allocates_only_on_hit() {
        let c = cfg(1);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(8, true));
        let mut rng = Pcg32::seeded(41);
        let elems = c.apm_elems(16);
        let f = unit(&mut rng, c.embed_dim);
        let apm: Vec<f32> = (0..elems).map(|i| i as f32).collect();
        let rows = 3usize;

        // Empty tier: misses leave the batch buffer unallocated.
        let mut buf: Vec<f32> = Vec::new();
        assert!(tier
            .lookup_fetch_lazy(0, &f, 32, 0.9, &mut buf, rows, 1)
            .is_none());
        assert!(buf.is_empty(), "a miss must not allocate the batch APM");

        tier.admit_batch(0, &[(f.as_slice(), apm.as_slice())], 0.9, 32)
            .unwrap();
        // Below-floor lookups still don't allocate.
        let far = unit(&mut rng, c.embed_dim);
        assert!(tier
            .lookup_fetch_lazy(0, &far, 32, 1.5, &mut buf, rows, 1)
            .is_none());
        assert!(buf.is_empty(), "a rejected hit must not allocate");
        // First real hit allocates the whole batch buffer and fills its row.
        assert!(tier
            .lookup_fetch_lazy(0, &f, 32, 0.9, &mut buf, rows, 1)
            .is_some());
        assert_eq!(buf.len(), rows * elems);
        assert_eq!(&buf[elems..2 * elems], &apm[..]);
        assert!(buf[..elems].iter().all(|&x| x == 0.0));
        assert!(buf[2 * elems..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn lookup_fetch_respects_similarity_floor() {
        let c = cfg(2);
        let tier = MemoTier::new(&c, 16, HnswParams::default(),
                                 &memo(8, true));
        let mut rng = Pcg32::seeded(9);
        let f = unit(&mut rng, c.embed_dim);
        let elems = c.apm_elems(16);
        let apm: Vec<f32> = (0..elems).map(|i| i as f32).collect();
        tier.admit_batch(1, &[(f.as_slice(), apm.as_slice())], 0.9, 32)
            .unwrap();
        let mut dst = vec![0.0f32; elems];
        // A floor above the achievable similarity rejects without copying.
        let far = unit(&mut rng, c.embed_dim);
        assert!(tier.lookup_fetch(1, &far, 32, 1.5, &mut dst).is_none());
        assert!(tier.lookup_fetch(1, &f, 32, 0.9, &mut dst).is_some());
        assert_eq!(dst, apm);
        // Layer 0 stayed untouched.
        assert!(tier.is_layer_empty(0));
        assert_eq!(tier.layer_len(1), 1);
    }
}
