//! Configuration system: model-family configs (mirroring
//! `python/compile/config.py`), serving-engine options, and memoization
//! options, all loadable from JSON files or CLI overrides.

pub mod json;

use crate::{Error, Result};
use self::json::Json;

/// Transformer family hyper-parameters (must match the python side; parsed
/// from `manifest.json`, never hard-coded).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub family: String,
    pub vocab_size: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub max_len: usize,
    pub num_classes: usize,
    pub rel_pos_buckets: usize,
    pub embed_dim: usize,
    pub embed_hidden: usize,
    pub embed_segments: usize,
    pub causal: bool,
}

impl ModelConfig {
    /// Parse from the manifest's `config` object.
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(ModelConfig {
            family: v.req_str("family")?.to_string(),
            vocab_size: v.req_usize("vocab_size")?,
            hidden: v.req_usize("hidden")?,
            layers: v.req_usize("layers")?,
            heads: v.req_usize("heads")?,
            ffn: v.req_usize("ffn")?,
            max_len: v.req_usize("max_len")?,
            num_classes: v.req_usize("num_classes")?,
            rel_pos_buckets: v.req_usize("rel_pos_buckets")?,
            embed_dim: v.req_usize("embed_dim")?,
            embed_hidden: v.req_usize("embed_hidden")?,
            embed_segments: v.req_usize("embed_segments")?,
            causal: v
                .get("causal")
                .and_then(Json::as_bool)
                .unwrap_or(false),
        })
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Elements in one memoized APM entry: heads × L × L.
    pub fn apm_elems(&self, seq_len: usize) -> usize {
        self.heads * seq_len * seq_len
    }

    /// Bytes of one APM entry (f32).
    pub fn apm_bytes(&self, seq_len: usize) -> usize {
        self.apm_elems(seq_len) * 4
    }
}

/// Memoization aggressiveness levels (paper Table 2). Thresholds apply to
/// the search-estimated similarity `1 − d` (d = embedding L2 distance).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoLevel {
    /// No memoization (the paper's baseline).
    Off,
    Conservative,
    Moderate,
    Aggressive,
}

impl MemoLevel {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "off" | "none" | "baseline" => MemoLevel::Off,
            "conservative" => MemoLevel::Conservative,
            "moderate" => MemoLevel::Moderate,
            "aggressive" => MemoLevel::Aggressive,
            other => {
                return Err(Error::config(format!(
                    "unknown memo level {other:?}"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            MemoLevel::Off => "off",
            MemoLevel::Conservative => "conservative",
            MemoLevel::Moderate => "moderate",
            MemoLevel::Aggressive => "aggressive",
        }
    }

    pub const ALL_ON: [MemoLevel; 3] =
        [MemoLevel::Conservative, MemoLevel::Moderate, MemoLevel::Aggressive];
}

/// How serving requests are sketched into affinity signatures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SignatureMode {
    /// Min-hash over token-bigram sets of the non-pad prefix. Cheap and
    /// model-free, but order-sensitive: paraphrases (same words, new
    /// order) sketch to unrelated signatures.
    Prefix,
    /// SimHash over the mean-pooled embedding-table rows of the non-pad
    /// prefix: a bag-of-words sketch in the model's own embedding space,
    /// so word-order variants and near-paraphrases share a bucket. Falls
    /// back to `Prefix` when no embedding table is loaded.
    Semantic,
}

impl SignatureMode {
    /// Parse a CLI/`--set` value.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "prefix" | "minhash" => SignatureMode::Prefix,
            "semantic" | "embedding" => SignatureMode::Semantic,
            other => {
                return Err(Error::config(format!(
                    "unknown signature mode {other:?} \
                     (want prefix|semantic)"
                )))
            }
        })
    }

    /// Canonical name (round-trips through [`SignatureMode::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            SignatureMode::Prefix => "prefix",
            SignatureMode::Semantic => "semantic",
        }
    }
}

/// Memoization options for the engine.
#[derive(Debug, Clone)]
pub struct MemoConfig {
    pub level: MemoLevel,
    /// Similarity threshold per level; `None` derives defaults calibrated
    /// per family (see `memo::thresholds`).
    pub threshold_override: Option<f64>,
    /// Enable the Eq. 3 selective-memoization performance model.
    pub selective: bool,
    /// Use memory-mapped APM gathering (vs the copy baseline).
    pub mmap_gather: bool,
    /// HNSW search breadth.
    pub ef_search: usize,
    /// Per-layer capacity of the *online* attention database (entries);
    /// 0 = unbounded. When the budget is reached, admission evicts via the
    /// reuse-aware clock.
    pub max_db_entries: usize,
    /// Admit APMs computed on the miss path into a serve-time (online)
    /// attention database, so cold or drifting workloads warm up instead
    /// of staying at the offline database's hit rate forever.
    pub online_admission: bool,
    /// Per-layer attempts to observe before the Eq. 3 admission gate
    /// activates (the warm-up window always admits).
    pub admission_min_attempts: u64,
    /// Skip admitting a miss row whose nearest stored neighbour (including
    /// rows admitted earlier in the same batch) already clears the
    /// similarity threshold — near-identical rows in one batch admit once.
    pub intra_batch_dedup: bool,
    /// Probe the published snapshot before paying the copy-on-write clone
    /// in `admit_batch`: a batch whose rows *all* dedup against stored
    /// entries (steady-state warm traffic) is served by lock-free reuse
    /// marks alone — no clone, no publish. Requires `intra_batch_dedup`;
    /// disable with `--no-dedup-prepass` to force every batch through the
    /// full publish path (A/B measurement, debugging).
    pub dedup_prepass: bool,
    /// Directory for the file-backed cold spill tier (`memo/cold.rs`).
    /// `None` (the default) disables spilling: clock victims are simply
    /// dropped. With a directory set, victims demote into per-layer
    /// cold arenas there, hot misses fall through to a cold lookup, and
    /// cold hits promote back into the hot tier (`--cold-tier-dir`).
    pub cold_tier_dir: Option<std::path::PathBuf>,
    /// Per-layer entry budget of the cold tier (`--cold-capacity`).
    /// Must be positive when `cold_tier_dir` is set; past it the oldest
    /// cold entries fall off the end (FIFO — twice-demoted is the end
    /// of the line).
    pub cold_capacity: usize,
    /// Bench-only A/B baseline: deep-copy the whole HNSW graph on every
    /// copy-on-write publish instead of sharing unchanged chunks with
    /// the displaced snapshot — the pre-generational O(n) write path.
    /// Never set in production; exists so `bench_online_memo` can prove
    /// the generational index's O(touched) publish against the
    /// full-clone cost on the same build.
    pub full_index_clone: bool,
    /// Force the scalar fallback in the unified kernel layer
    /// (`crate::kernels`) instead of the runtime-dispatched AVX2 paths.
    /// A/B baseline for the SIMD similarity + blocked-attention work;
    /// also settable via `ATTMEMO_SCALAR_KERNELS=1`. Never set in
    /// production.
    pub scalar_kernels: bool,
}

impl Default for MemoConfig {
    fn default() -> Self {
        MemoConfig {
            level: MemoLevel::Moderate,
            threshold_override: None,
            selective: true,
            mmap_gather: true,
            ef_search: 48,
            max_db_entries: 0,
            online_admission: false,
            admission_min_attempts: 64,
            intra_batch_dedup: true,
            dedup_prepass: true,
            cold_tier_dir: None,
            cold_capacity: 0,
            full_index_clone: false,
            scalar_kernels: false,
        }
    }
}

/// Serving-engine options (dynamic batcher + server).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Max requests fused into one engine batch. Must be one of the
    /// batch sizes lowered by aot.py (the engine pads up to the nearest).
    pub max_batch: usize,
    /// Batch-formation wait budget.
    pub max_wait_ms: u64,
    /// Bounded request-queue depth (backpressure beyond this).
    pub queue_depth: usize,
    /// Sequence length served (must be lowered in the artifacts).
    pub seq_len: usize,
    /// TCP bind address for `attmemo serve`.
    pub bind: String,
    /// Worker threads handling connections.
    pub io_threads: usize,
    /// Engine replicas pulling from the shared request queue. Replicas
    /// share one online `MemoTier`, so warm-ups are visible across all of
    /// them while their forward passes run in parallel.
    pub replicas: usize,
    /// Affinity buckets in front of the batchers: requests whose token
    /// prefixes sketch alike land in the same bucket, each batcher
    /// prefers draining its home buckets (stealing from the fullest
    /// bucket when idle), so similar requests batch together and raise
    /// the intra-batch dedup yield. `1` = a single FIFO bucket, i.e.
    /// affinity routing off (`--no-affinity`).
    pub affinity_buckets: usize,
    /// How requests are sketched into affinity signatures
    /// (`--signature-mode prefix|semantic`). Semantic mode buckets by
    /// meaning through the model's embedding table; when no table is
    /// loaded, a semantic *default* falls back to the prefix min-hash
    /// with a warning, while an *explicitly requested* semantic mode
    /// (see [`ServingConfig::signature_explicit`]) is a hard startup
    /// error.
    pub signature_mode: SignatureMode,
    /// Whether `signature_mode` was set explicitly by the operator
    /// (`--signature-mode` / `--set signature_mode=…`) rather than
    /// inherited from a config default. Explicit semantic mode must not
    /// silently degrade to the prefix min-hash.
    pub signature_explicit: bool,
    /// Non-pad prefix tokens both signature modes sketch over
    /// (`--signature-prefix-len`, `--set signature_prefix_len=N`).
    pub signature_prefix_len: usize,
    /// Let the router adaptively grow/shrink the bucket space
    /// (power-of-two, drain-and-requeue) when the observed steal rate or
    /// bucket-occupancy skew shows the partition fighting the traffic
    /// (`--adaptive-buckets`).
    pub affinity_adaptive: bool,
    /// Upper bound on adaptive bucket growth
    /// (`--set affinity_max_buckets=N`).
    pub affinity_max_buckets: usize,
    /// Iteration-level (continuous) batching: sequences join and leave
    /// the in-flight batch at every step boundary and responses stream
    /// back as chunks (`--continuous-batching`). Off by default — the
    /// legacy fixed-batch path stays the baseline
    /// (`--no-continuous-batching`).
    pub continuous_batching: bool,
    /// Slots in the continuous scheduler's in-flight batch
    /// (`--max-inflight`). Plays the role `max_batch` plays on the
    /// legacy path.
    pub max_inflight: usize,
    /// Stall budget (ms) before a backpressured sequence yields its
    /// in-flight slot and is parked (`--client-stall-ms`). `0` parks on
    /// the first full-channel chunk.
    pub client_stall_ms: u64,
    /// Bound of each request's streaming-chunk channel — the per-client
    /// backpressure depth (`--set chunk_depth=N`).
    pub chunk_depth: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            max_batch: 32,
            max_wait_ms: 4,
            queue_depth: 1024,
            seq_len: 128,
            bind: "127.0.0.1:7191".into(),
            io_threads: 2,
            replicas: 1,
            affinity_buckets: 8,
            signature_mode: SignatureMode::Prefix,
            signature_explicit: false,
            signature_prefix_len: 32,
            affinity_adaptive: false,
            affinity_max_buckets: 64,
            continuous_batching: false,
            max_inflight: 32,
            client_stall_ms: 50,
            chunk_depth: 4,
        }
    }
}

impl ServingConfig {
    /// Apply `key=value` overrides (CLI `--set`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "max_batch" => self.max_batch = parse_num(key, value)?,
            "max_wait_ms" => self.max_wait_ms = parse_num(key, value)? as u64,
            "queue_depth" => self.queue_depth = parse_num(key, value)?,
            "seq_len" => self.seq_len = parse_num(key, value)?,
            "bind" => self.bind = value.to_string(),
            "io_threads" => self.io_threads = parse_num(key, value)?,
            "replicas" => self.replicas = parse_num(key, value)?.max(1),
            "affinity_buckets" => {
                self.affinity_buckets = parse_num(key, value)?.max(1)
            }
            "signature_mode" => {
                self.signature_mode = SignatureMode::parse(value)?;
                self.signature_explicit = true;
            }
            "signature_prefix_len" => {
                self.signature_prefix_len = parse_num(key, value)?.max(1)
            }
            "affinity_adaptive" => {
                self.affinity_adaptive = parse_bool(key, value)?
            }
            "affinity_max_buckets" => {
                self.affinity_max_buckets = parse_num(key, value)?.max(1)
            }
            "continuous_batching" => {
                self.continuous_batching = parse_bool(key, value)?
            }
            "max_inflight" => {
                self.max_inflight = parse_num(key, value)?.max(1)
            }
            "client_stall_ms" => {
                self.client_stall_ms = parse_num(key, value)? as u64
            }
            "chunk_depth" => {
                self.chunk_depth = parse_num(key, value)?.max(1)
            }
            other => {
                return Err(Error::config(format!(
                    "unknown serving option {other:?}"
                )))
            }
        }
        Ok(())
    }
}

fn parse_num(key: &str, value: &str) -> Result<usize> {
    value
        .parse()
        .map_err(|_| Error::config(format!("{key}: bad number {value:?}")))
}

fn parse_bool(key: &str, value: &str) -> Result<bool> {
    match value {
        "1" | "true" | "on" | "yes" => Ok(true),
        "0" | "false" | "off" | "no" => Ok(false),
        other => {
            Err(Error::config(format!("{key}: bad bool {other:?}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_cfg_json() -> Json {
        Json::parse(
            r#"{"family":"bert","vocab_size":256,"hidden":128,"layers":4,
                "heads":4,"ffn":256,"max_len":128,"num_classes":2,
                "rel_pos_buckets":32,"embed_dim":128,"embed_hidden":256,
                "embed_segments":8,"causal":false,"head_dim":32}"#,
        )
        .unwrap()
    }

    #[test]
    fn model_config_parses() {
        let c = ModelConfig::from_json(&demo_cfg_json()).unwrap();
        assert_eq!(c.family, "bert");
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.apm_bytes(128), 4 * 128 * 128 * 4);
    }

    #[test]
    fn model_config_missing_field_errors() {
        let v = Json::parse(r#"{"family":"bert"}"#).unwrap();
        assert!(ModelConfig::from_json(&v).is_err());
    }

    #[test]
    fn memo_level_roundtrip() {
        for l in [MemoLevel::Off, MemoLevel::Conservative, MemoLevel::Moderate,
                  MemoLevel::Aggressive] {
            assert_eq!(MemoLevel::parse(l.name()).unwrap(), l);
        }
        assert!(MemoLevel::parse("bogus").is_err());
    }

    #[test]
    fn serving_overrides() {
        let mut s = ServingConfig::default();
        s.set("max_batch", "8").unwrap();
        s.set("bind", "0.0.0.0:1").unwrap();
        s.set("replicas", "3").unwrap();
        assert_eq!(s.max_batch, 8);
        assert_eq!(s.bind, "0.0.0.0:1");
        assert_eq!(s.replicas, 3);
        s.set("replicas", "0").unwrap();
        assert_eq!(s.replicas, 1, "replica count clamps to at least one");
        s.set("affinity_buckets", "4").unwrap();
        assert_eq!(s.affinity_buckets, 4);
        s.set("affinity_buckets", "0").unwrap();
        assert_eq!(s.affinity_buckets, 1,
                   "bucket count clamps to at least one");
        assert!(s.set("nope", "1").is_err());
        assert!(s.set("max_batch", "x").is_err());
    }

    #[test]
    fn signature_and_adaptive_overrides() {
        let mut s = ServingConfig::default();
        assert_eq!(s.signature_mode, SignatureMode::Prefix);
        assert!(!s.signature_explicit, "defaults are not explicit");
        assert_eq!(s.signature_prefix_len, 32);
        assert!(!s.affinity_adaptive);
        s.set("signature_mode", "semantic").unwrap();
        assert_eq!(s.signature_mode, SignatureMode::Semantic);
        assert!(s.signature_explicit,
                "a --set override is an explicit operator request");
        s.set("signature_mode", "minhash").unwrap();
        assert_eq!(s.signature_mode, SignatureMode::Prefix);
        assert!(s.set("signature_mode", "quantum").is_err());
        s.set("signature_prefix_len", "0").unwrap();
        assert_eq!(s.signature_prefix_len, 1, "prefix length clamps to 1");
        s.set("signature_prefix_len", "48").unwrap();
        assert_eq!(s.signature_prefix_len, 48);
        s.set("affinity_adaptive", "true").unwrap();
        assert!(s.affinity_adaptive);
        s.set("affinity_adaptive", "0").unwrap();
        assert!(!s.affinity_adaptive);
        assert!(s.set("affinity_adaptive", "maybe").is_err());
        s.set("affinity_max_buckets", "128").unwrap();
        assert_eq!(s.affinity_max_buckets, 128);
    }

    #[test]
    fn continuous_batching_overrides() {
        let s = ServingConfig::default();
        assert!(!s.continuous_batching,
                "legacy fixed batching stays the default");
        assert_eq!(s.max_inflight, 32);
        assert_eq!(s.client_stall_ms, 50);
        assert_eq!(s.chunk_depth, 4);
        let mut s = ServingConfig::default();
        s.set("continuous_batching", "on").unwrap();
        assert!(s.continuous_batching);
        s.set("continuous_batching", "0").unwrap();
        assert!(!s.continuous_batching);
        assert!(s.set("continuous_batching", "perhaps").is_err());
        s.set("max_inflight", "0").unwrap();
        assert_eq!(s.max_inflight, 1, "in-flight slots clamp to 1");
        s.set("max_inflight", "64").unwrap();
        assert_eq!(s.max_inflight, 64);
        s.set("client_stall_ms", "0").unwrap();
        assert_eq!(s.client_stall_ms, 0, "zero budget parks immediately");
        s.set("chunk_depth", "0").unwrap();
        assert_eq!(s.chunk_depth, 1, "chunk channel bound clamps to 1");
    }

    #[test]
    fn signature_mode_roundtrip() {
        for m in [SignatureMode::Prefix, SignatureMode::Semantic] {
            assert_eq!(SignatureMode::parse(m.name()).unwrap(), m);
        }
        assert!(SignatureMode::parse("bogus").is_err());
    }
}
