//! Hand-rolled JSON codec (serde is not vendored in this offline registry).
//!
//! Full RFC 8259 value model with the subset of ergonomics the crate needs:
//! typed accessors, path lookups, and a writer. Used for `manifest.json`,
//! `vocab.json`, `templates.json` and run-configuration files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!(
                "trailing data at byte {} of {}",
                p.pos,
                p.bytes.len()
            )));
        }
        Ok(v)
    }

    /// Read + parse a file.
    pub fn from_file(path: &std::path::Path) -> Result<Json> {
        let src = std::fs::read_to_string(path)?;
        Json::parse(&src).map_err(|e| {
            Error::Json(format!("{}: {e}", path.display()))
        })
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // -- checked accessors (error messages name the field) ------------------

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing field {key:?}")))
    }
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Json(format!("field {key:?} not a string")))
    }
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Json(format!("field {key:?} not a number")))
    }
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Json(format!("field {key:?} not a number")))
    }
    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| Error::Json(format!("field {key:?} not an array")))
    }

    /// usize vector from an array field.
    pub fn usize_vec(&self, key: &str) -> Result<Vec<usize>> {
        self.req_arr(key)?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Json(format!("{key:?}: non-number")))
            })
            .collect()
    }

    // -- writer --------------------------------------------------------------

    /// Serialise (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers used by metric/report writers.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: impl Into<String>) -> Json {
    Json::Str(x.into())
}
pub fn arr(xs: Vec<Json>) -> Json {
    Json::Arr(xs)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected {:?} at byte {}",
                b as char, self.pos
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {other:?} at byte {}",
                self.pos
            ))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number {text:?}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| {
                                    Error::Json("bad \\u escape".into())
                                })?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| {
                                    Error::Json("bad \\u escape".into())
                                })?,
                                16,
                            )
                            .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        other => {
                            return Err(Error::Json(format!(
                                "bad escape {other:?}"
                            )))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| Error::Json("invalid utf-8".into()))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected , or ] got {other:?} at {}",
                        self.pos
                    )))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected , or }} got {other:?} at {}",
                        self.pos
                    )))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.req_arr("a").unwrap().len(), 2);
        assert_eq!(
            v.req_arr("a").unwrap()[1].req_str("b").unwrap(),
            "x"
        );
        assert_eq!(v.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"Aé");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":true}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn checked_accessors_report_field_names() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        let err = v.req_str("missing").unwrap_err().to_string();
        assert!(err.contains("missing"));
        let err = v.req_str("a").unwrap_err().to_string();
        assert!(err.contains("a"));
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
