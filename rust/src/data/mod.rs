//! Corpus handling: vocab, the template workload generator (mirroring
//! `python/compile/datagen.py` exactly via the exported `templates.json`),
//! and dataset accuracy evaluation.

pub mod synth;
pub mod tokenizer;

pub use synth::SynthGen;
pub use tokenizer::Vocab;
