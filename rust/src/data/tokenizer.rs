//! Vocabulary loaded from `artifacts/vocab.json` (written by datagen.py).
//!
//! Serving requests arrive as text; this tokenizer maps whitespace-split
//! words to the training vocab (unknown words → `[unk]`), pads/truncates to
//! the serving sequence length, and decodes ids back for debugging.

use std::collections::HashMap;
use std::path::Path;

use crate::config::json::Json;
use crate::{Error, Result};

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
pub const UNK: i32 = 3;

/// Word ↔ id tables.
pub struct Vocab {
    word_to_id: HashMap<String, i32>,
    id_to_word: Vec<String>,
}

impl Vocab {
    /// Load `vocab.json` (`{"vocab": {word: id}, "specials": [...]}`).
    pub fn load(path: &Path) -> Result<Vocab> {
        let v = Json::from_file(path)?;
        let obj = v
            .req("vocab")?
            .as_obj()
            .ok_or_else(|| Error::Json("vocab not an object".into()))?;
        let mut word_to_id = HashMap::new();
        let mut max_id = 0usize;
        for (w, id) in obj {
            let id = id
                .as_usize()
                .ok_or_else(|| Error::Json(format!("vocab id for {w:?}")))?;
            word_to_id.insert(w.clone(), id as i32);
            max_id = max_id.max(id);
        }
        let mut id_to_word = vec![String::new(); max_id + 1];
        for (w, &id) in &word_to_id {
            id_to_word[id as usize] = w.clone();
        }
        Ok(Vocab { word_to_id, id_to_word })
    }

    /// In-memory vocab for tests.
    pub fn from_pairs(pairs: &[(&str, i32)]) -> Vocab {
        let mut word_to_id = HashMap::new();
        let mut max_id = 0;
        for &(w, id) in pairs {
            word_to_id.insert(w.to_string(), id);
            max_id = max_id.max(id as usize);
        }
        let mut id_to_word = vec![String::new(); max_id + 1];
        for (w, &id) in &word_to_id {
            id_to_word[id as usize] = w.clone();
        }
        Vocab { word_to_id, id_to_word }
    }

    pub fn len(&self) -> usize {
        self.word_to_id.len()
    }

    pub fn is_empty(&self) -> bool {
        self.word_to_id.is_empty()
    }

    pub fn id(&self, word: &str) -> i32 {
        self.word_to_id.get(word).copied().unwrap_or(UNK)
    }

    pub fn word(&self, id: i32) -> &str {
        self.id_to_word
            .get(id as usize)
            .map(String::as_str)
            .unwrap_or("[unk]")
    }

    /// Encode text → `[cls] tokens… [sep]`, padded/truncated to `seq_len`.
    pub fn encode(&self, text: &str, seq_len: usize) -> Vec<i32> {
        let mut ids = vec![CLS];
        for w in text.split_whitespace() {
            if ids.len() + 1 >= seq_len {
                break;
            }
            ids.push(self.id(&w.to_lowercase()));
        }
        ids.push(SEP);
        ids.resize(seq_len, PAD);
        ids
    }

    /// Decode ids → text (skipping pads).
    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&i| i != PAD)
            .map(|&i| self.word(i))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v() -> Vocab {
        Vocab::from_pairs(&[
            ("[pad]", 0),
            ("[cls]", 1),
            ("[sep]", 2),
            ("[unk]", 3),
            ("the", 4),
            ("film", 5),
            ("was", 6),
            ("great", 7),
        ])
    }

    #[test]
    fn encode_wraps_and_pads() {
        let ids = v().encode("the film was great", 8);
        assert_eq!(ids, vec![1, 4, 5, 6, 7, 2, 0, 0]);
    }

    #[test]
    fn encode_truncates() {
        let ids = v().encode("the film was great the film was great", 6);
        assert_eq!(ids.len(), 6);
        assert_eq!(ids[0], 1);
        assert_eq!(ids[5], 2);
    }

    #[test]
    fn unknown_words_map_to_unk() {
        let ids = v().encode("the zebra", 6);
        assert_eq!(ids[2], UNK);
    }

    #[test]
    fn decode_roundtrip() {
        let voc = v();
        let ids = voc.encode("the film was great", 8);
        assert_eq!(voc.decode(&ids), "[cls] the film was great [sep]");
    }
}
