//! Serving-time workload generator: renders the SAME template bank the
//! python datagen used for training (loaded from `artifacts/templates.json`),
//! so inference requests are distributionally identical to the corpus the
//! attention database was populated from — the property the paper's
//! selective-memoization transfer argument (§5.4) relies on.

use std::collections::HashMap;
use std::path::Path;

use crate::config::json::Json;
use crate::data::tokenizer::{CLS, PAD, SEP};
use crate::tensor::tensor::IdTensor;
use crate::util::Pcg32;
use crate::{Error, Result};

/// One template item: a literal token id or a slot name.
#[derive(Debug, Clone)]
enum Item {
    Word(i32),
    Slot(String),
}

/// The template bank + slot pools.
pub struct SynthGen {
    templates: Vec<Vec<Item>>,
    slots: HashMap<String, Vec<i32>>,
    rng: Pcg32,
}

impl SynthGen {
    /// Load `templates.json`.
    pub fn load(path: &Path, seed: u64) -> Result<SynthGen> {
        let v = Json::from_file(path)?;
        let mut templates = Vec::new();
        for t in v.req_arr("templates")? {
            let items = t
                .as_arr()
                .ok_or_else(|| Error::Json("template not an array".into()))?
                .iter()
                .map(|item| {
                    if let Some(w) = item.get("word").and_then(Json::as_i64) {
                        Ok(Item::Word(w as i32))
                    } else if let Some(s) =
                        item.get("slot").and_then(Json::as_str)
                    {
                        Ok(Item::Slot(s.to_string()))
                    } else {
                        Err(Error::Json("template item missing word/slot".into()))
                    }
                })
                .collect::<Result<Vec<_>>>()?;
            templates.push(items);
        }
        let mut slots = HashMap::new();
        for (name, ids) in v
            .req("slots")?
            .as_obj()
            .ok_or_else(|| Error::Json("slots not an object".into()))?
        {
            let pool = ids
                .as_arr()
                .ok_or_else(|| Error::Json("slot pool not an array".into()))?
                .iter()
                .map(|x| x.as_i64().map(|i| i as i32))
                .collect::<Option<Vec<i32>>>()
                .ok_or_else(|| Error::Json("slot pool: non-number".into()))?;
            slots.insert(name.clone(), pool);
        }
        Ok(SynthGen { templates, slots, rng: Pcg32::seeded(seed) })
    }

    fn pick(&mut self, pool_name: &str) -> Result<i32> {
        let pool = self.slots.get(pool_name).ok_or_else(|| {
            Error::config(format!("no slot pool {pool_name:?}"))
        })?;
        Ok(pool[self.rng.range_usize(0, pool.len())])
    }

    /// Render one sentence agreeing with `target` (0 = negative,
    /// 1 = positive) — the mirror of python `datagen._render`.
    fn render(&mut self, ti: usize, target: usize) -> Result<Vec<i32>> {
        let template = self.templates[ti].clone();
        let mut out = Vec::with_capacity(template.len() + 2);
        for item in template {
            match item {
                Item::Word(w) => out.push(w),
                Item::Slot(s) => {
                    let (neg, slot) = match s.strip_prefix('!') {
                        Some(rest) => (true, rest),
                        None => (false, s.as_str()),
                    };
                    let agree = target == 1;
                    let pool = match slot {
                        "+A" => if agree { "+A" } else { "-A" },
                        "-A" => if agree { "-A" } else { "+A" },
                        "+V" => if agree { "+V" } else { "-V" },
                        "-V" => if agree { "-V" } else { "+V" },
                        "N" => "N",
                        "I" => "I",
                        other => {
                            return Err(Error::config(format!(
                                "unknown slot {other:?}"
                            )))
                        }
                    };
                    if neg {
                        out.push(self.pick("NEG")?);
                        // Negation flips the adjective pool.
                        let flipped = match pool {
                            "+A" => "-A",
                            "-A" => "+A",
                            p => p,
                        };
                        out.push(self.pick(flipped)?);
                    } else {
                        out.push(self.pick(pool)?);
                    }
                }
            }
        }
        Ok(out)
    }

    /// Generate one classification sequence; returns (ids, label).
    pub fn gen_sequence(&mut self, seq_len: usize) -> Result<(Vec<i32>, i32)> {
        let target = self.rng.range_usize(0, 2);
        let mut row = vec![CLS];
        loop {
            let ti = self.rng.range_usize(0, self.templates.len());
            let sent = self.render(ti, target)?;
            if row.len() + sent.len() + 1 > seq_len {
                break;
            }
            row.extend_from_slice(&sent);
            row.push(SEP);
            if row.len() > seq_len * 3 / 4 || self.rng.next_f32() < 0.3 {
                break;
            }
        }
        row.resize(seq_len, PAD);
        Ok((row, target as i32))
    }

    /// Generate a batch `[n, seq_len]` with labels.
    pub fn gen_batch(&mut self, n: usize,
                     seq_len: usize) -> Result<(IdTensor, Vec<i32>)> {
        let mut data = Vec::with_capacity(n * seq_len);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let (row, label) = self.gen_sequence(seq_len)?;
            data.extend_from_slice(&row);
            labels.push(label);
        }
        Ok((IdTensor::new(vec![n, seq_len], data)?, labels))
    }

    pub fn num_templates(&self) -> usize {
        self.templates.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> SynthGen {
        let json = r#"{
            "templates": [
                [{"word": 10}, {"slot": "N"}, {"word": 11}, {"slot": "+A"}],
                [{"word": 12}, {"slot": "!+A"}]
            ],
            "slots": {
                "+A": [20, 21], "-A": [30, 31], "+V": [40], "-V": [41],
                "N": [50, 51], "I": [60], "NEG": [70]
            }
        }"#;
        let dir = std::env::temp_dir().join("attmemo_synth_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("templates.json");
        std::fs::write(&p, json).unwrap();
        SynthGen::load(&p, 42).unwrap()
    }

    #[test]
    fn sequences_have_frame_and_label() {
        let mut g = demo();
        for _ in 0..50 {
            let (ids, label) = g.gen_sequence(16).unwrap();
            assert_eq!(ids.len(), 16);
            assert_eq!(ids[0], CLS);
            assert!((0..=1).contains(&label));
            // Sentiment words agree with the label.
            let pos = ids.iter().any(|&t| t == 20 || t == 21);
            let neg_adj = ids.iter().any(|&t| t == 30 || t == 31);
            let negator = ids.iter().any(|&t| t == 70);
            if label == 1 && !negator {
                assert!(pos && !neg_adj, "{ids:?}");
            }
            if label == 0 && !negator {
                assert!(neg_adj && !pos, "{ids:?}");
            }
        }
    }

    #[test]
    fn batch_shapes() {
        let mut g = demo();
        let (ids, labels) = g.gen_batch(5, 12).unwrap();
        assert_eq!(ids.shape, vec![5, 12]);
        assert_eq!(labels.len(), 5);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = {
            let mut g = demo();
            g.gen_batch(3, 16).unwrap().0
        };
        let b = {
            let mut g = demo();
            g.gen_batch(3, 16).unwrap().0
        };
        assert_eq!(a, b);
    }
}
