//! PCG32 pseudo-random number generator.
//!
//! Deterministic, seedable, and fast; used everywhere randomness is needed
//! (synthetic data, HNSW level draws, property tests, workload generators)
//! so that every experiment in EXPERIMENTS.md is exactly reproducible.

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform float in `[0, 1)` with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` using Lemire rejection.
    #[inline]
    pub fn gen_range(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-12 {
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Geometric-like level draw used by HNSW: `floor(-ln(u) * mult)`.
    pub fn hnsw_level(&mut self, mult: f64) -> usize {
        let u = self.next_f64().max(1e-12);
        ((-u.ln()) * mult).floor() as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_respects_bound() {
        let mut rng = Pcg32::seeded(9);
        for bound in [1u32, 2, 3, 7, 100, 1 << 20] {
            for _ in 0..200 {
                assert!(rng.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_covers_small_domain() {
        let mut rng = Pcg32::seeded(11);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[rng.gen_range(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments_are_sane() {
        let mut rng = Pcg32::seeded(13);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::seeded(17);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::seeded(19);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }
}
