//! Fixed-size threadpool with a shared injector queue.
//!
//! Tokio is not available offline, so the serving layer runs on this pool:
//! worker threads pull boxed jobs from a `Mutex<VecDeque>` guarded by a
//! condvar. `scoped` offers fork-join parallelism for the offline builders.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done_cv: Condvar,
    done_mu: Mutex<()>,
}

/// A fixed pool of worker threads executing submitted closures FIFO.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `n` workers (clamped to at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mu: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("attmemo-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job; panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        assert!(!self.shared.shutdown.load(Ordering::SeqCst), "pool shut down");
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(f));
        self.shared.cv.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_mu.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done_cv.wait(guard).unwrap();
        }
    }

    /// Fork-join: run `f(i)` for `i in 0..n` on the pool, blocking until all
    /// complete. Panics in jobs are contained per-thread and reported.
    pub fn scoped<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        for i in 0..n {
            let f = f.clone();
            self.execute(move || f(i));
        }
        self.wait_idle();
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        // Contain panics so one bad job doesn't kill the worker.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = shared.done_mu.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scoped_covers_every_index() {
        let pool = ThreadPool::new(3);
        let hits = Arc::new(Mutex::new(vec![false; 50]));
        let h2 = hits.clone();
        pool.scoped(50, move |i| {
            h2.lock().unwrap()[i] = true;
        });
        assert!(hits.lock().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let counter = Arc::new(AtomicU64::new(0));
        let c = counter.clone();
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(1);
        pool.wait_idle();
    }
}
