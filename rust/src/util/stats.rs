//! Latency/throughput statistics: streaming summaries and fixed-bucket
//! histograms (an offline-friendly replacement for `hdrhistogram`).

/// Streaming summary over f64 samples: count / mean / min / max / percentiles.
///
/// Samples are retained (benchmarks here are small: at most a few hundred
/// thousand points), so percentiles are exact.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn record(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (0 when fewer than two samples).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    /// Minimum (0 when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min).min(f64::INFINITY)
    }

    /// Maximum (0 when empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile in `[0, 100]` by nearest-rank; 0 when empty.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.samples.len() as f64 - 1.0)).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    /// Median.
    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// 99th percentile.
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// Total of all samples.
    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// Absorb another summary's samples.
    pub fn merge(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }
}

/// Fixed-bucket histogram over `[lo, hi)` with uniform bucket width, plus
/// overflow/underflow buckets. Used for similarity-score distributions
/// (paper Figs. 3, 12, 15) and latency distributions.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    /// Histogram with `n` uniform buckets over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n > 0);
        Histogram { lo, hi, buckets: vec![0; n], underflow: 0, overflow: 0 }
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((x - self.lo) / (self.hi - self.lo) * self.buckets.len() as f64) as usize;
            let last = self.buckets.len() - 1;
            self.buckets[idx.min(last)] += 1;
        }
    }

    /// Bucket counts (excluding under/overflow).
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Total observations including under/overflow.
    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Fraction of observations with value >= `threshold`.
    pub fn frac_at_least(&self, threshold: f64) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let width = (self.hi - self.lo) / self.buckets.len() as f64;
        let mut cnt = self.overflow;
        for (i, b) in self.buckets.iter().enumerate() {
            let lo_edge = self.lo + i as f64 * width;
            if lo_edge >= threshold {
                cnt += b;
            }
        }
        cnt as f64 / total as f64
    }

    /// Render the bucket edges + counts as `(edge_lo, count)` rows.
    pub fn rows(&self) -> Vec<(f64, u64)> {
        let width = (self.hi - self.lo) / self.buckets.len() as f64;
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + i as f64 * width, c))
            .collect()
    }

    /// ASCII bar-chart (for bench output).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.buckets.iter().copied().max().unwrap_or(1).max(1);
        let bw = (self.hi - self.lo) / self.buckets.len() as f64;
        let mut out = String::new();
        for (i, &c) in self.buckets.iter().enumerate() {
            let bar = "#".repeat((c as usize * width / max as usize).min(width));
            out.push_str(&format!(
                "  [{:6.3},{:6.3}) {:>7} |{}\n",
                self.lo + i as f64 * bw,
                self.lo + (i + 1) as f64 * bw,
                c,
                bar
            ));
        }
        out
    }
}

/// Monotonic stopwatch in seconds.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    /// Start timing.
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    /// Elapsed milliseconds.
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.sum() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::new();
        for i in 0..101 {
            s.record(i as f64);
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.p99(), 99.0);
    }

    #[test]
    fn summary_empty_is_zero() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn summary_stddev() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.record(x);
        }
        assert!((s.stddev() - 2.138).abs() < 0.01);
    }

    #[test]
    fn histogram_counts_and_bounds() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.record(-0.1);
        h.record(0.0);
        h.record(0.05);
        h.record(0.95);
        h.record(1.0);
        h.record(2.0);
        assert_eq!(h.total(), 6);
        assert_eq!(h.buckets()[0], 2);
        assert_eq!(h.buckets()[9], 1);
    }

    #[test]
    fn histogram_frac_at_least() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for i in 0..10 {
            h.record(i as f64 / 10.0 + 0.05);
        }
        let f = h.frac_at_least(0.5);
        assert!((f - 0.5).abs() < 1e-9, "{f}");
    }

    #[test]
    fn histogram_rows_align_with_edges() {
        let h = Histogram::new(0.0, 2.0, 4);
        let rows = h.rows();
        assert_eq!(rows.len(), 4);
        assert!((rows[1].0 - 0.5).abs() < 1e-12);
    }
}
