//! Small self-contained substrates: PRNG, statistics, threadpool, logger.
//!
//! The build environment is offline (the only dependencies are the small
//! crates vendored under `rust/vendor/`), so these are implemented from
//! scratch instead of pulling `rand`, `hdrhistogram`, `rayon` or
//! `env_logger`.

pub mod logger;
pub mod rng;
pub mod stats;
pub mod threadpool;

pub use rng::Pcg32;
pub use stats::Summary;
pub use threadpool::ThreadPool;
