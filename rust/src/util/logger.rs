//! Minimal `log` facade backend (env_logger is not vendored).
//!
//! Level comes from `ATTMEMO_LOG` (error|warn|info|debug|trace), default
//! `info`. Output is line-oriented on stderr with elapsed-seconds stamps.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    level: LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record<'_>) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger once; later calls are no-ops. Returns the level used.
pub fn init() -> LevelFilter {
    let level = match std::env::var("ATTMEMO_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now(), level });
    let _ = log::set_logger(logger);
    log::set_max_level(logger.level);
    logger.level
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        let a = super::init();
        let b = super::init();
        assert_eq!(a, b);
    }
}
