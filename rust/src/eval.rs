//! Dataset evaluation helpers shared by the CLI, examples and benches:
//! accuracy, latency and memoization-rate measurement over a dataset, for
//! the baseline and each memoization level (papers Tables 5/7/8, Fig. 10).

use crate::serving::engine::Engine;
use crate::tensor::tensor::IdTensor;
use crate::util::stats::Stopwatch;
use crate::Result;

/// Outcome of one evaluation run.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub sequences: usize,
    pub correct: usize,
    pub seconds: f64,
    pub memo_rate: f64,
}

impl EvalResult {
    pub fn accuracy(&self) -> f64 {
        if self.sequences == 0 {
            0.0
        } else {
            self.correct as f64 / self.sequences as f64
        }
    }

    pub fn throughput(&self) -> f64 {
        if self.seconds <= 0.0 {
            0.0
        } else {
            self.sequences as f64 / self.seconds
        }
    }
}

/// Run `ids` through the engine in `batch`-sized chunks.
///
/// `baseline` forces the fused non-memoized path regardless of the engine's
/// memo configuration.
pub fn evaluate(engine: &mut Engine, ids: &IdTensor, labels: &[i32],
                batch: usize, baseline: bool) -> Result<EvalResult> {
    let n = ids.shape[0];
    let mut correct = 0usize;
    let hits_before: u64 =
        engine.stats.layers.iter().map(|l| l.hits).sum();
    let total_before: u64 =
        engine.stats.layers.first().map_or(0, |l| l.total);
    let sw = Stopwatch::start();
    let mut start = 0;
    while start < n {
        let count = batch.min(n - start);
        let chunk = ids.slice0(start, count)?;
        let result = if baseline {
            engine.infer_baseline(&chunk)?
        } else {
            engine.infer(&chunk)?
        };
        for (i, &pred) in result.labels.iter().enumerate() {
            if pred == labels[start + i] {
                correct += 1;
            }
        }
        start += count;
    }
    let seconds = sw.secs();
    let layers = engine.stats.layers.len().max(1) as u64;
    let hits: u64 = engine.stats.layers.iter().map(|l| l.hits).sum();
    let total: u64 = engine.stats.layers.first().map_or(0, |l| l.total);
    let denom = (total - total_before) * layers;
    let memo_rate = if denom == 0 || baseline {
        0.0
    } else {
        (hits - hits_before) as f64 / denom as f64
    };
    Ok(EvalResult { sequences: n, correct, seconds, memo_rate })
}
