"""Static perf-trajectory dashboard over BENCH_history.jsonl.

Renders one inline-SVG sparkline per numeric summary key across the
recorded bench history, into a single self-contained HTML file — no
dependencies beyond the stdlib, so CI can run it right after the bench
smoke job and upload the page as an artifact.

Usage:
    python3 python/bench_dashboard.py BENCH_history.jsonl \
        docs/bench_history.html

Lines that fail to parse are skipped with a warning; a short or missing
history still produces a valid (if sparse) page.
"""

import html
import json
import sys

WIDTH, HEIGHT, PAD = 260, 48, 4

PAGE = """<!doctype html>
<html><head><meta charset="utf-8"><title>bench history</title>
<style>
 body {{ font: 14px/1.5 system-ui, sans-serif; margin: 2em auto;
        max-width: 64em; color: #1a1a2e; }}
 table {{ border-collapse: collapse; width: 100%; }}
 td, th {{ padding: .4em .8em; border-bottom: 1px solid #ddd;
          text-align: left; vertical-align: middle; }}
 td.num {{ font-variant-numeric: tabular-nums; }}
 svg {{ display: block; }}
</style></head><body>
<h1>Bench history</h1>
<p>{runs} recorded run(s) from <code>{src}</code>. Newest value,
range, and per-run sparkline for every numeric summary key.</p>
<table>
<tr><th>key</th><th>last</th><th>min</th><th>max</th><th>trend</th></tr>
{rows}
</table></body></html>
"""


def load_history(path):
    """Parse the jsonl history into a list of dicts, skipping bad lines."""
    entries = []
    try:
        with open(path, encoding="utf-8") as fh:
            for n, line in enumerate(fh, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    entries.append(json.loads(line))
                except ValueError:
                    print(f"warning: {path}:{n}: unparseable line "
                          "skipped", file=sys.stderr)
    except OSError as e:
        print(f"warning: {e}; rendering empty dashboard",
              file=sys.stderr)
    return entries


def numeric_keys(entries):
    """Keys holding numbers, in order of first appearance."""
    keys = []
    for e in entries:
        for k, v in e.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                if k not in keys:
                    keys.append(k)
    return keys


def sparkline(values):
    """Inline SVG polyline through the series, min..max normalized."""
    pts = [(i, v) for i, v in enumerate(values) if v is not None]
    if not pts:
        return "<svg width='%d' height='%d'></svg>" % (WIDTH, HEIGHT)
    lo = min(v for _, v in pts)
    hi = max(v for _, v in pts)
    span_x = max(len(values) - 1, 1)
    span_y = (hi - lo) or 1.0
    coords = []
    for i, v in pts:
        x = PAD + (WIDTH - 2 * PAD) * i / span_x
        y = PAD + (HEIGHT - 2 * PAD) * (1 - (v - lo) / span_y)
        coords.append("%.1f,%.1f" % (x, y))
    dot = coords[-1].split(",")
    return (
        "<svg width='%d' height='%d'>"
        "<polyline points='%s' fill='none' stroke='#4361ee' "
        "stroke-width='1.5'/>"
        "<circle cx='%s' cy='%s' r='2.5' fill='#4361ee'/></svg>"
        % (WIDTH, HEIGHT, " ".join(coords), dot[0], dot[1])
    )


def fmt(v):
    if v is None:
        return "&mdash;"
    return "%g" % round(v, 6)


def render(entries, src):
    rows = []
    for key in numeric_keys(entries):
        series = [e.get(key) for e in entries]
        present = [v for v in series if v is not None]
        rows.append(
            "<tr><td><code>%s</code></td><td class='num'>%s</td>"
            "<td class='num'>%s</td><td class='num'>%s</td><td>%s</td>"
            "</tr>"
            % (html.escape(key), fmt(present[-1]), fmt(min(present)),
               fmt(max(present)), sparkline(series))
        )
    return PAGE.format(runs=len(entries), src=html.escape(src),
                       rows="\n".join(rows))


def main(argv):
    if len(argv) != 3:
        print("usage: bench_dashboard.py <history.jsonl> <out.html>",
              file=sys.stderr)
        return 2
    entries = load_history(argv[1])
    page = render(entries, argv[1])
    with open(argv[2], "w", encoding="utf-8") as fh:
        fh.write(page)
    print(f"wrote {argv[2]}: {len(entries)} run(s), "
          f"{len(numeric_keys(entries))} key(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
