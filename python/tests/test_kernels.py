"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and dtypes as mandated by DESIGN.md §5; the
deadline is disabled because interpret-mode pallas is slow on CPU.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import attention, mlp_embed, ref, similarity

SETTINGS = dict(max_examples=12, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


shapes = st.tuples(
    st.sampled_from([1, 2, 3]),          # batch
    st.sampled_from([1, 2, 4]),          # heads
    st.sampled_from([8, 16, 24, 32]),    # seq len
    st.sampled_from([4, 8, 16]),         # head dim
)


@hypothesis.given(shape=shapes, causal=st.booleans(),
                  seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(**SETTINGS)
def test_apm_matches_ref(shape, causal, seed):
    rng = np.random.default_rng(seed)
    b, nh, l, dh = shape
    q = rand(rng, (b, nh, l, dh))
    k = rand(rng, (b, nh, l, dh))
    got = attention.apm_pallas(q, k, causal=causal, block_q=8)
    want = ref.apm_ref(q, k, causal=causal)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@hypothesis.given(shape=shapes, seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(**SETTINGS)
def test_apm_bias_matches_ref(shape, seed):
    rng = np.random.default_rng(seed)
    b, nh, l, dh = shape
    q = rand(rng, (b, nh, l, dh))
    k = rand(rng, (b, nh, l, dh))
    bias = rand(rng, (nh, l, l))
    got = attention.apm_pallas(q, k, bias=bias, block_q=8)
    want = ref.apm_ref(q, k, bias=bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@hypothesis.given(shape=shapes, causal=st.booleans(),
                  seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(**SETTINGS)
def test_flash_matches_ref(shape, causal, seed):
    rng = np.random.default_rng(seed)
    b, nh, l, dh = shape
    q = rand(rng, (b, nh, l, dh))
    k = rand(rng, (b, nh, l, dh))
    v = rand(rng, (b, nh, l, dh))
    got = attention.attention_pallas(q, k, v, causal=causal,
                                     block_q=8, block_k=8)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_flash_block_shapes_dont_change_result():
    rng = np.random.default_rng(0)
    q = rand(rng, (2, 2, 32, 8))
    k = rand(rng, (2, 2, 32, 8))
    v = rand(rng, (2, 2, 32, 8))
    a = attention.attention_pallas(q, k, v, block_q=8, block_k=8)
    b = attention.attention_pallas(q, k, v, block_q=16, block_k=32)
    c = attention.attention_pallas(q, k, v, block_q=32, block_k=16)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-6)


def test_apm_rows_are_stochastic():
    rng = np.random.default_rng(1)
    q = rand(rng, (2, 2, 16, 8))
    k = rand(rng, (2, 2, 16, 8))
    apm = attention.apm_pallas(q, k, block_q=8)
    np.testing.assert_allclose(jnp.sum(apm, -1), 1.0, rtol=1e-5)


def test_causal_apm_is_lower_triangular():
    rng = np.random.default_rng(2)
    q = rand(rng, (1, 1, 16, 8))
    k = rand(rng, (1, 1, 16, 8))
    apm = np.asarray(attention.apm_pallas(q, k, causal=True, block_q=8))
    upper = np.triu(apm[0, 0], k=1)
    assert np.abs(upper).max() < 1e-7


def test_apply_apm_matches_einsum():
    rng = np.random.default_rng(3)
    q = rand(rng, (2, 2, 16, 8))
    k = rand(rng, (2, 2, 16, 8))
    v = rand(rng, (2, 2, 16, 8))
    apm = ref.apm_ref(q, k)
    got = attention.apply_apm_pallas(apm, v)
    want = jnp.einsum("bhqk,bhkd->bhqd", apm, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@hypothesis.given(
    b=st.sampled_from([1, 2, 5, 8]),
    dims=st.sampled_from([(16, 8, 4), (32, 16, 8), (64, 32, 16)]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_mlp_embed_matches_ref(b, dims, seed):
    rng = np.random.default_rng(seed)
    d_in, d_h, d_out = dims
    pooled = rand(rng, (b, d_in))
    ws = [
        rand(rng, (d_in, d_h)) * 0.1, rand(rng, (d_h,)) * 0.1,
        rand(rng, (d_h, d_h)) * 0.1, rand(rng, (d_h,)) * 0.1,
        rand(rng, (d_h, d_out)) * 0.1, rand(rng, (d_out,)) * 0.1,
    ]
    got = mlp_embed.mlp_embed_pallas(pooled, *ws, block_b=4)
    want = ref.mlp_embed_ref(pooled, *ws)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_mlp_embed_output_is_unit_norm():
    rng = np.random.default_rng(4)
    pooled = rand(rng, (6, 32))
    ws = [rand(rng, s) * 0.2 for s in
          [(32, 16), (16,), (16, 16), (16,), (16, 8), (8,)]]
    out = mlp_embed.mlp_embed_pallas(pooled, *ws)
    np.testing.assert_allclose(
        jnp.linalg.norm(out, axis=-1), 1.0, rtol=1e-4)


@hypothesis.given(
    n=st.sampled_from([1, 2, 4]),
    nh=st.sampled_from([1, 2, 4]),
    l=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_similarity_matches_ref(n, nh, l, seed):
    rng = np.random.default_rng(seed)
    a = jax.nn.softmax(rand(rng, (n, nh, l, l)), axis=-1)
    b = jax.nn.softmax(rand(rng, (n, nh, l, l)), axis=-1)
    got = similarity.similarity_pallas(a, b)
    want = ref.similarity_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_similarity_bounds_and_identity():
    rng = np.random.default_rng(5)
    a = jax.nn.softmax(rand(rng, (3, 2, 16, 16)), axis=-1)
    b = jax.nn.softmax(rand(rng, (3, 2, 16, 16)), axis=-1)
    s_ab = np.asarray(similarity.similarity_pallas(a, b))
    assert (s_ab >= -1e-5).all() and (s_ab <= 1 + 1e-5).all()
    s_aa = np.asarray(similarity.similarity_pallas(a, a))
    np.testing.assert_allclose(s_aa, 1.0, atol=1e-6)


def test_segment_pool_shapes():
    rng = np.random.default_rng(6)
    h = rand(rng, (2, 16, 8))
    pooled = ref.segment_pool_ref(h, 4)
    assert pooled.shape == (2, 32)
    # Each segment mean matches the naive computation.
    np.testing.assert_allclose(
        pooled[0, :8], np.asarray(h)[0, :4].mean(axis=0), rtol=1e-6)
    with pytest.raises(AssertionError):
        ref.segment_pool_ref(h, 5)
