"""AOT lowering tests: every graph kind lowers to parseable HLO text with
the expected parameter arity, and the fast-mode build round-trips."""

import os

import jax
import pytest

from compile import aot
from compile.config import ModelConfig


@pytest.fixture(autouse=True)
def pallas_on(monkeypatch):
    monkeypatch.setenv("ATTMEMO_NO_PALLAS", "0")


CFG = ModelConfig(family="bert", vocab_size=256, max_len=32, hidden=32,
                  layers=2, heads=2, ffn=64, rel_pos_buckets=8,
                  embed_dim=16, embed_hidden=32, embed_segments=4)


@pytest.mark.parametrize("kind,extra", [
    ("embed", 0), ("attn_scores", 0), ("attn_apply", 0),
    ("layer_full", 0), ("classifier", 0), ("mlp_embed", 0),
])
def test_graph_lowers_to_hlo_text(tmp_path, kind, extra):
    out = tmp_path / f"{kind}.hlo.txt"
    names, nbytes = aot.lower_graph(CFG, kind, 2, 16, str(out))
    text = out.read_text()
    assert text.startswith("HloModule"), text[:40]
    # Parameter count in the entry computation matches the manifest names.
    entry = [l for l in text.splitlines() if "parameter(" in l]
    assert len(entry) >= len(names)
    assert nbytes == len(text)


def test_deberta_scores_takes_rel_emb(tmp_path):
    cfg = ModelConfig(family="deberta", vocab_size=256, max_len=32,
                      hidden=32, layers=2, heads=2, ffn=64,
                      rel_pos_buckets=8, embed_dim=16, embed_hidden=32,
                      embed_segments=4)
    names, _ = aot.lower_graph(cfg, "attn_scores", 1, 16,
                               str(tmp_path / "d.hlo.txt"))
    assert names[-1] == "rel_emb"


def test_gpt_uses_lm_head(tmp_path):
    cfg = ModelConfig(family="gpt", vocab_size=256, max_len=32, hidden=32,
                      layers=2, heads=2, ffn=64, rel_pos_buckets=8,
                      embed_dim=16, embed_hidden=32, embed_segments=4)
    names, _ = aot.lower_graph(cfg, "lm_head", 1, 16,
                               str(tmp_path / "g.hlo.txt"))
    assert names == ["hidden", "tok_emb"]
    with pytest.raises(ValueError):
        aot.graph_signature(cfg, "nonsense", 1, 16)


def test_graph_plan_covers_serving_batches():
    plan = aot.graph_plan(ModelConfig(family="bert", vocab_size=256))
    batches = {b for (_, b, l) in plan if l == 128}
    assert {1, 8, 32} <= batches
    sweeps = {l for (_, _, l) in plan}
    assert {16, 32, 64, 128} <= sweeps


def test_hlo_text_is_reparseable(tmp_path):
    """The text must survive a parse through XLA's own parser — this is the
    exact path the rust loader takes."""
    from jax._src.lib import xla_client as xc
    out = tmp_path / "x.hlo.txt"
    aot.lower_graph(CFG, "attn_scores", 1, 16, str(out))
    # round-trip: text -> computation -> text
    comp = xc._xla.hlo_module_from_text(out.read_text())
    assert comp is not None
