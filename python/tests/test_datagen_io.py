"""Corpus generator + artifact-format tests (the rust side must parse
everything these emit)."""

import json
import struct

import numpy as np
import pytest

from compile import datagen, io_utils

VOCAB = datagen.build_vocab()


def test_vocab_has_specials_first():
    for i, s in enumerate(datagen.SPECIALS):
        assert VOCAB[s] == i
    assert len(set(VOCAB.values())) == len(VOCAB)


def test_padded_vocab_size():
    assert datagen.padded_vocab_size(VOCAB) % 128 == 0
    assert datagen.padded_vocab_size(VOCAB) >= len(VOCAB)


def test_classification_labels_match_sentiment_words():
    ids, labels = datagen.gen_classification(64, 32, 0, VOCAB)
    pos = {VOCAB[w] for w in datagen.POS_ADJ + datagen.VERBS_LIKE}
    neg = {VOCAB[w] for w in datagen.NEG_ADJ + datagen.VERBS_HATE}
    negators = {VOCAB[w] for w in datagen.NEGATORS}
    for row, label in zip(ids, labels):
        toks = set(int(t) for t in row)
        if toks & negators:
            continue  # negated clauses legitimately mix pools
        has_pos, has_neg = bool(toks & pos), bool(toks & neg)
        if label == 1:
            assert has_pos, row
        else:
            assert has_neg, row


def test_classification_deterministic_by_seed():
    a = datagen.gen_classification(8, 32, 5, VOCAB)
    b = datagen.gen_classification(8, 32, 5, VOCAB)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    c = datagen.gen_classification(8, 32, 6, VOCAB)
    assert not np.array_equal(a[0], c[0])


def test_sequences_start_with_cls_and_pad():
    ids, _ = datagen.gen_classification(16, 24, 1, VOCAB)
    assert (ids[:, 0] == datagen.CLS).all()
    assert ids.shape == (16, 24)


def test_lm_sequences_are_fully_packed():
    ids, _ = datagen.gen_lm(4, 48, 2, VOCAB)
    assert (ids != datagen.PAD).all()


def test_dataset_binary_roundtrip(tmp_path):
    ids, labels = datagen.gen_classification(10, 16, 3, VOCAB)
    p = tmp_path / "ds.bin"
    datagen.write_dataset(p, ids, labels)
    raw = p.read_bytes()
    assert raw[:4] == b"ATDS"
    n, seq = struct.unpack("<II", raw[4:12])
    assert (n, seq) == (10, 16)
    got_ids = np.frombuffer(raw[12:12 + n * seq * 4], "<i4").reshape(n, seq)
    got_labels = np.frombuffer(raw[12 + n * seq * 4:], "<i4")
    np.testing.assert_array_equal(got_ids, ids)
    np.testing.assert_array_equal(got_labels, labels)


def test_templates_export_covers_all_slots(tmp_path):
    datagen.export_vocab_and_templates(
        VOCAB, tmp_path / "vocab.json", tmp_path / "templates.json")
    t = json.loads((tmp_path / "templates.json").read_text())
    assert len(t["templates"]) == len(datagen.TEMPLATES)
    for pool in ("+A", "-A", "+V", "-V", "N", "I", "NEG"):
        assert t["slots"][pool], pool
    v = json.loads((tmp_path / "vocab.json").read_text())
    assert v["vocab"]["[cls]"] == 1


def test_tensor_bin_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = [
        ("a", rng.normal(size=(3, 4)).astype(np.float32)),
        ("ids", np.arange(6, dtype=np.int32).reshape(2, 3)),
        ("b", rng.normal(size=(5,)).astype(np.float32)),
    ]
    p = tmp_path / "w.bin"
    entries = io_utils.write_tensor_bin(p, tensors)
    assert [e["dtype"] for e in entries] == ["f32", "i32", "f32"]
    back = io_utils.read_tensor_bin(p, entries)
    for name, arr in tensors:
        np.testing.assert_array_equal(back[name], arr)


def test_longer_sequences_pack_more_clauses():
    """The Fig. 12 premise: longer inputs contain more sentence frames."""
    short, _ = datagen.gen_classification(64, 16, 9, VOCAB)
    long_, _ = datagen.gen_classification(64, 128, 9, VOCAB)
    seps_short = (short == datagen.SEP).sum(axis=1).mean()
    seps_long = (long_ == datagen.SEP).sum(axis=1).mean()
    assert seps_long > seps_short * 2
