"""L2 model-graph tests: shapes, family deltas, pallas/oracle equivalence,
and training-substrate sanity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datagen, model as M, train
from compile.config import ModelConfig

VOCAB = datagen.build_vocab()
VS = datagen.padded_vocab_size(VOCAB)


def cfg_for(family, **kw):
    return ModelConfig(family=family, vocab_size=VS, max_len=32,
                       hidden=32, layers=2, heads=2, ffn=64,
                       rel_pos_buckets=8, embed_dim=16, embed_hidden=32,
                       embed_segments=4, **kw)


@pytest.fixture(params=["bert", "roberta", "deberta", "gpt"])
def family(request):
    return request.param


@pytest.fixture
def setup(family, monkeypatch):
    monkeypatch.setenv("ATTMEMO_NO_PALLAS", "1")
    cfg = cfg_for(family)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ids, labels = datagen.gen_classification(4, 32, 0, VOCAB)
    return cfg, params, jnp.asarray(ids), labels


def test_forward_shapes(setup):
    cfg, params, ids, _ = setup
    logits = M.forward_logits(cfg, params, ids)
    if cfg.family == "gpt":
        assert logits.shape == (4, 32, VS)
    else:
        assert logits.shape == (4, cfg.num_classes)
    assert bool(jnp.isfinite(logits).all())


def test_collect_returns_per_layer_states(setup):
    cfg, params, ids, _ = setup
    _, collected = M.forward_hidden(cfg, params, ids, collect=True)
    assert len(collected) == cfg.layers
    for hidden, apm in collected:
        assert hidden.shape == (4, 32, cfg.hidden)
        assert apm.shape == (4, cfg.heads, 32, 32)
        np.testing.assert_allclose(jnp.sum(apm, -1), 1.0, rtol=1e-4)


def test_split_path_equals_layer_full(setup):
    """attn_scores + attn_apply must equal layer_full exactly — the engine
    relies on this to mix memoized and fused layers."""
    cfg, params, ids, _ = setup
    emb = M.embed_graph(cfg)
    x = emb(ids, *[params[n] for n in M.EMBED_WEIGHTS])
    lw = [params[f"l0_{n}"] for n in M.LAYER_WEIGHTS]
    extra = [params["rel_emb"]] if cfg.family == "deberta" else []
    apm = M.attn_scores_graph(cfg)(
        x, lw[0], lw[1], lw[2], lw[3], lw[8], lw[9], *extra)
    split = M.attn_apply_graph(cfg)(x, apm, *lw)
    fused = M.layer_full_graph(cfg)(x, *lw, *extra)
    np.testing.assert_allclose(split, fused, rtol=1e-4, atol=1e-5)


def test_pallas_and_oracle_graphs_agree(family):
    """The shipped (pallas) graphs must match the training (oracle) path."""
    cfg = cfg_for(family)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    ids, _ = datagen.gen_classification(2, 32, 1, VOCAB)
    ids = jnp.asarray(ids)
    os.environ["ATTMEMO_NO_PALLAS"] = "1"
    ref_logits = M.forward_logits(cfg, params, ids)
    os.environ["ATTMEMO_NO_PALLAS"] = "0"
    pal_logits = M.forward_logits(cfg, params, ids)
    os.environ["ATTMEMO_NO_PALLAS"] = "1"
    np.testing.assert_allclose(pal_logits, ref_logits, rtol=2e-4, atol=2e-4)


def test_causal_family_ignores_future(monkeypatch):
    monkeypatch.setenv("ATTMEMO_NO_PALLAS", "1")
    cfg = cfg_for("gpt")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    ids, _ = datagen.gen_lm(1, 32, 0, VOCAB)
    ids2 = ids.copy()
    ids2[0, -1] = (ids2[0, -1] + 1) % VS  # perturb the last token
    a = M.forward_logits(cfg, params, jnp.asarray(ids))
    b = M.forward_logits(cfg, params, jnp.asarray(ids2))
    # Position t logits depend only on tokens ≤ t.
    np.testing.assert_allclose(a[0, :-1], b[0, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(a[0, -1], b[0, -1])


def test_deberta_bias_changes_scores(monkeypatch):
    monkeypatch.setenv("ATTMEMO_NO_PALLAS", "1")
    cfg = cfg_for("deberta")
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    ids, _ = datagen.gen_classification(2, 32, 3, VOCAB)
    x = M.embed_graph(cfg)(jnp.asarray(ids),
                           *[params[n] for n in M.EMBED_WEIGHTS])
    lw = [params[f"l0_{n}"] for n in M.LAYER_WEIGHTS]
    rel = params["rel_emb"] * 20.0  # amplify so the delta is unambiguous
    with_bias = M.attn_scores_graph(cfg)(
        x, lw[0], lw[1], lw[2], lw[3], lw[8], lw[9], rel)
    zero_rel = jnp.zeros_like(params["rel_emb"])
    without = M.attn_scores_graph(cfg)(
        x, lw[0], lw[1], lw[2], lw[3], lw[8], lw[9], zero_rel)
    assert float(jnp.abs(with_bias - without).max()) > 1e-4


def test_param_order_is_complete(family):
    cfg = cfg_for(family)
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    order = M.param_order(cfg)
    assert sorted(order) == sorted(params.keys())


def test_training_step_reduces_loss(monkeypatch):
    monkeypatch.setenv("ATTMEMO_NO_PALLAS", "1")
    cfg = cfg_for("roberta")
    ids, labels = datagen.gen_classification(64, 32, 7, VOCAB)
    _, hist = train.train_task(cfg, ids, labels, steps=60, batch=16,
                               lr=2e-3, log=lambda *_: None)
    assert hist[-1] < hist[0], f"{hist[0]} -> {hist[-1]}"


def test_pruning_reaches_target_sparsity(monkeypatch):
    monkeypatch.setenv("ATTMEMO_NO_PALLAS", "1")
    cfg = cfg_for("bert")
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    masks = train.prune_masks(params, 0.85)
    sparse = train.apply_masks(params, masks)
    s = train.sparsity_of(sparse)
    assert 0.8 <= s <= 0.9, s


def test_embedder_training_learns_similarity(monkeypatch):
    monkeypatch.setenv("ATTMEMO_NO_PALLAS", "1")
    cfg = cfg_for("bert")
    params = M.init_params(cfg, jax.random.PRNGKey(6))
    ids, _ = datagen.gen_classification(32, 32, 8, VOCAB)
    hiddens, apms = train.collect_states(cfg, params, ids, batch=8)
    assert hiddens.shape == (cfg.layers, 32, 32, cfg.hidden)
    assert apms.shape == (cfg.layers, 32, cfg.heads, 32, 32)
    _, hist = train.train_embedder(cfg, hiddens, apms, steps=80,
                                   batch=32, log=lambda *_: None)
    assert hist[-1] < hist[0]
