"""Artifact serialisation shared between aot.py and the tests.

Formats consumed by the rust runtime (rust/src/runtime/artifacts.rs):

* ``weights/<name>.bin`` — concatenated little-endian f32 tensor data; the
  manifest records each tensor's (name, shape, offset-in-floats, len).
* ``manifest.json`` — single index of families, graphs, datasets, fixtures.
* ``fixtures/<family>.bin`` — named f32/i32 tensors for cross-language
  numeric integration tests (same layout as weights bins plus a dtype tag).
"""

import json
import os

import numpy as np


def write_tensor_bin(path, tensors):
    """Write ordered (name, np.ndarray) pairs; returns manifest entries.

    Float tensors are stored as f32, integer tensors as i32; `dtype` is
    recorded per entry.
    """
    entries = []
    offset = 0
    with open(path, "wb") as f:
        for name, arr in tensors:
            arr = np.asarray(arr)
            if np.issubdtype(arr.dtype, np.integer):
                data = arr.astype("<i4")
                dtype = "i32"
            else:
                data = arr.astype("<f4")
                dtype = "f32"
            f.write(data.tobytes())
            entries.append({
                "name": name,
                "shape": list(arr.shape),
                "offset": offset,          # in elements (4 bytes each)
                "len": int(arr.size),
                "dtype": dtype,
            })
            offset += int(arr.size)
    return entries


def read_tensor_bin(path, entries):
    """Inverse of write_tensor_bin (used by pytest round-trip checks)."""
    raw = np.fromfile(path, dtype="<u4")
    out = {}
    for e in entries:
        chunk = raw[e["offset"]:e["offset"] + e["len"]]
        if e["dtype"] == "i32":
            arr = chunk.view("<i4")
        else:
            arr = chunk.view("<f4")
        out[e["name"]] = arr.reshape(e["shape"]).copy()
    return out


def write_manifest(path, manifest):
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)


def ensure_dir(path):
    os.makedirs(path, exist_ok=True)
    return path
