"""Lower additional attn_scores batch sizes into existing artifacts.

§Perf: the engine computes scores only for memoization *misses*, packed
into a sub-batch. With only {1,8,32} lowered, a 3-miss sub-batch pads to 8
and costs as much as the full batch. This utility adds {2,4,16} for
`attn_scores` (the only sub-batched graph) without re-running training.

Usage: cd python && python -m compile.lower_extra ../artifacts
"""

import json
import os
import sys

from . import aot
from .config import ModelConfig

EXTRA_BATCHES = (2, 4, 16)


def lower_extra(out_dir: str) -> None:
    os.environ["ATTMEMO_NO_PALLAS"] = "0"   # ship the pallas kernels
    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    seq = manifest["serving_seq_len"]
    have = {(g["family"], g["kind"], g["batch"], g["seq_len"])
            for g in manifest["graphs"]}
    for fam, info in manifest["families"].items():
        cfg = ModelConfig(**{
            k: v for k, v in info["config"].items()
            if k not in ("head_dim", "causal")
        })
        for b in EXTRA_BATCHES:
            key = (fam, "attn_scores", b, seq)
            if key in have:
                continue
            name = f"{fam}_attn_scores_b{b}_s{seq}"
            path = os.path.join(out_dir, "hlo", name + ".hlo.txt")
            names, nbytes = aot.lower_graph(cfg, "attn_scores", b, seq, path)
            manifest["graphs"].append({
                "family": fam, "kind": "attn_scores", "batch": b,
                "seq_len": seq, "path": f"hlo/{name}.hlo.txt",
                "params": names, "bytes": nbytes,
            })
            print(f"[extra] lowered {name}")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[extra] manifest updated ({len(manifest['graphs'])} graphs)")


if __name__ == "__main__":
    lower_extra(sys.argv[1] if len(sys.argv) > 1 else "../artifacts")
