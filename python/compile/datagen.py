"""Synthetic corpora standing in for SST-2 and WikiText-2 (DESIGN.md §2).

The memoization opportunity the paper exploits comes from *shared syntactic
frames with varying content words* ("I like apple." vs "I like banana.").
This generator reproduces that structure explicitly: a bank of sentence
templates with sentiment-bearing slots. Sequences drawn from the same
template produce near-identical attention structure — exactly the
cross-sequence APM similarity of paper Figs. 3/12/15 — while slot words
carry the label, so the classification task is learnable but not trivial
(negators flip polarity; the *last* sentiment clause wins in contrastive
templates).

Everything is exported to ``artifacts/``: the vocab, the template bank
(token ids + slot specs) and pre-generated train/test datasets, so the rust
workload generator (``data::synth``) draws from the *identical*
distribution at serving time.
"""

import json
import struct

import numpy as np

PAD, CLS, SEP, UNK = 0, 1, 2, 3
SPECIALS = ["[pad]", "[cls]", "[sep]", "[unk]"]

POS_ADJ = """great wonderful brilliant delightful superb excellent charming
 moving gripping fresh clever inspired stunning masterful heartfelt rich
 funny sharp tender luminous elegant vivid thrilling graceful sincere
 powerful polished radiant warm triumphant""".split()

NEG_ADJ = """terrible awful dreadful boring bland clumsy tedious hollow
 stale messy lifeless shallow grating dull sloppy forgettable flat
 pretentious weak murky plodding contrived lazy soulless tiresome cheap
 muddled annoying pointless dismal""".split()

NOUNS = """film movie plot script story acting cast ending dialogue pacing
 scene soundtrack直 direction premise sequel drama comedy thriller documentary
 performance cinematography character narrative romance adaptation""".split()
NOUNS = [n for n in NOUNS if n.isascii()]

VERBS_LIKE = ["loved", "enjoyed", "adored", "admired", "savored"]
VERBS_HATE = ["hated", "loathed", "despised", "dreaded", "resented"]
INTENS = ["really", "truly", "utterly", "absolutely", "quite", "deeply"]
FILLER = """the a an it this that was is but and because while though
 with of in by for audience critics viewers i we everyone nobody felt
 seemed looked turned became remained started ended overall frankly
 honestly surprisingly somewhat rather never always often barely""".split()
NEGATORS = ["not", "hardly", "never"]

# Templates: items are literal words, or slots interpreted relative to the
# sequence's *target label* (chosen first, uniformly):
#   +A  sentiment adjective AGREEING with the target
#   -A  sentiment adjective OPPOSING the target (contrastive clauses)
#   +V/-V  sentiment verbs likewise
#   !+A agreeing adjective expressed by negating an opposing one
#       ("not terrible" for a positive target)
#   N   neutral noun, I intensifier
# Every clause in a sequence is rendered with the same target, so the label
# is bag-of-words learnable, while contrastive/negated templates still
# reward attention to word order.
TEMPLATES = [
    ["the", "N", "was", "+A"],
    ["the", "N", "was", "I", "+A"],
    ["i", "+V", "the", "N", "because", "it", "was", "+A"],
    ["a", "I", "+A", "N", "with", "a", "+A", "ending"],
    ["the", "N", "started", "-A", "but", "ended", "+A"],
    ["critics", "felt", "the", "N", "was", "!+A"],
    ["this", "N", "is", "+A", "and", "the", "N", "is", "+A"],
    ["nobody", "expected", "such", "a", "+A", "N"],
    ["overall", "a", "I", "+A", "piece", "of", "work"],
    ["the", "acting", "was", "+A", "though", "the", "N", "was", "I", "+A"],
    ["it", "seemed", "-A", "at", "first", "but", "became", "I", "+A"],
    ["we", "+V", "every", "I", "+A", "scene"],
]


def build_vocab():
    """Vocab = specials + every word reachable from the template bank."""
    words = []
    for t in TEMPLATES:
        for w in t:
            if w not in ("N", "I", "+A", "-A", "+V", "-V", "!+A", "!-A") \
                    and w not in words:
                words.append(w)
    for group in (POS_ADJ, NEG_ADJ, NOUNS, VERBS_LIKE, VERBS_HATE, INTENS,
                  FILLER, NEGATORS):
        for w in group:
            if w not in words:
                words.append(w)
    vocab = {w: i + len(SPECIALS) for i, w in enumerate(words)}
    for i, s in enumerate(SPECIALS):
        vocab[s] = i
    return vocab


def _render(template, rng, vocab, target):
    """Render one template to token ids, agreeing with ``target`` (0/1)."""
    adj = (NEG_ADJ, POS_ADJ)
    verb = (VERBS_HATE, VERBS_LIKE)
    ids = []
    for item in template:
        neg = item.startswith("!")
        slot = item[1:] if neg else item
        if slot == "+A":
            pool = adj[target]
        elif slot == "-A":
            pool = adj[1 - target]
        elif slot == "+V":
            pool = verb[target]
        elif slot == "-V":
            pool = verb[1 - target]
        elif slot == "N":
            pool = NOUNS
        elif slot == "I":
            pool = INTENS
        else:
            ids.append(vocab[item])
            continue
        if neg:
            # "not <opposing adjective>" expresses agreement with target.
            ids.append(vocab[NEGATORS[rng.integers(len(NEGATORS))]])
            pool = adj[1 - target] if slot == "+A" else adj[target]
        ids.append(vocab[pool[rng.integers(len(pool))]])
    return ids


def gen_classification(n, seq_len, seed, vocab):
    """n sequences of fixed seq_len: [cls] sent [sep] sent [sep] … [pad]*.

    Sentences are appended until the length budget is filled (longer
    sequences therefore contain more sentiment clauses — more attention
    structure, reproducing the Fig. 12 length effect). Label = polarity of
    the last sentiment clause (documented rule).
    """
    rng = np.random.default_rng(seed)
    ids = np.zeros((n, seq_len), dtype=np.int32)
    labels = np.zeros((n,), dtype=np.int32)
    for s in range(n):
        target = int(rng.integers(2))
        row = [CLS]
        while True:
            t = TEMPLATES[rng.integers(len(TEMPLATES))]
            sent = _render(t, rng, vocab, target)
            if len(row) + len(sent) + 1 > seq_len:
                break
            row += sent + [SEP]
            # Short sequences keep one sentence; long ones pack several.
            if len(row) > seq_len * 3 // 4 or rng.random() < 0.3:
                break
        row = row[:seq_len] + [PAD] * max(0, seq_len - len(row))
        ids[s] = np.asarray(row, dtype=np.int32)
        labels[s] = target
    return ids, labels


def gen_lm(n, seq_len, seed, vocab):
    """LM corpus: templated sentences joined by [sep]; next-token targets."""
    rng = np.random.default_rng(seed)
    ids = np.zeros((n, seq_len), dtype=np.int32)
    for s in range(n):
        row = [CLS]
        while len(row) < seq_len:
            t = TEMPLATES[rng.integers(len(TEMPLATES))]
            sent = _render(t, rng, vocab, int(rng.integers(2)))
            row += sent + [SEP]
        ids[s] = np.asarray(row[:seq_len], dtype=np.int32)
    labels = np.zeros((n,), dtype=np.int32)  # unused for LM
    return ids, labels


def write_dataset(path, ids, labels):
    """Binary dataset: magic 'ATDS', u32 n, u32 seq_len, ids i32 LE row-major,
    labels i32 LE."""
    n, seq_len = ids.shape
    with open(path, "wb") as f:
        f.write(b"ATDS")
        f.write(struct.pack("<II", n, seq_len))
        f.write(ids.astype("<i4").tobytes())
        f.write(labels.astype("<i4").tobytes())


def export_vocab_and_templates(vocab, path_vocab, path_templates):
    """JSON exports consumed by rust data::synth (identical generator)."""
    with open(path_vocab, "w") as f:
        json.dump({"vocab": vocab, "specials": SPECIALS}, f)
    slots = {
        "+A": [vocab[w] for w in POS_ADJ],
        "-A": [vocab[w] for w in NEG_ADJ],
        "+V": [vocab[w] for w in VERBS_LIKE],
        "-V": [vocab[w] for w in VERBS_HATE],
        "N": [vocab[w] for w in NOUNS],
        "I": [vocab[w] for w in INTENS],
        "NEG": [vocab[w] for w in NEGATORS],
    }
    templates = []
    for t in TEMPLATES:
        items = []
        for item in t:
            if item in ("+A", "-A", "+V", "-V", "N", "I", "!+A", "!-A"):
                items.append({"slot": item})
            else:
                items.append({"word": vocab[item]})
        templates.append(items)
    with open(path_templates, "w") as f:
        json.dump({"templates": templates, "slots": slots}, f)


def padded_vocab_size(vocab, multiple=128):
    """Vocab size rounded up (keeps embedding matmuls MXU-tile aligned)."""
    n = len(vocab)
    return (n + multiple - 1) // multiple * multiple
