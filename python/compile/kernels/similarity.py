"""L1 Pallas kernel for the paper's Eq. 1 similarity score.

Used by the offline attention-database builder and the evaluation
harnesses: given two batches of APMs it returns, per pair, the
total-variation-based similarity ``1 - mean_p TV(A[p,:], A'[p,:])``.

The kernel reduces one (pair, head) grid cell at a time; the [L, L]
difference tile is formed in VMEM and reduced to a scalar partial that the
grid accumulates into the per-pair output (heads are averaged).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sim_kernel(a_ref, b_ref, o_ref, *, heads):
    """Accumulate 1 - mean-row-TV for one head into the pair's slot."""
    a = a_ref[0, 0]
    b = b_ref[0, 0]
    tv = 0.5 * jnp.sum(jnp.abs(a - b), axis=-1)     # [L]
    partial = (1.0 - jnp.mean(tv)) / heads

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])

    o_ref[0] += partial.astype(o_ref.dtype)


def similarity_pallas(a, b, *, interpret=True):
    """Similarity scores for paired APM batches.

    a, b: [N, nH, L, L] row-stochastic; returns [N] in [0, 1].
    Matches :func:`compile.kernels.ref.similarity_ref`.
    """
    n, nh, l, _ = a.shape
    grid = (n, nh)
    spec = pl.BlockSpec((1, 1, l, l), lambda i, j: (i, j, 0, 0))
    o_spec = pl.BlockSpec((1,), lambda i, j: (i,))
    return pl.pallas_call(
        functools.partial(_sim_kernel, heads=float(nh)),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=interpret,
    )(a, b)
