"""L1 Pallas attention kernels.

Two kernels cover the paper's attention hot-spot:

* :func:`apm_pallas` — produces the attention probability matrix
  ``softmax(Q·Kᵀ·scale)`` explicitly. This is the *memoization subject*: the
  rust coordinator stores these APMs in the attention database and, on a
  hit, skips this kernel entirely (paper §5).
* :func:`attention_pallas` — fused FlashAttention-style kernel
  (Q·Kᵀ → streaming online softmax → ·V) used by the non-memoized
  ``layer_full`` fast path; the L×L score matrix never materialises.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets
CPUs; these kernels are authored for TPU semantics. The grid tiles the
query dimension so one grid cell holds a ``block_q × dh`` Q tile plus the
K/V panels in VMEM; contractions are shaped for 128-wide MXU tiles
(H = 128, dh = 32). The HBM↔VMEM schedule that a CUDA version would express
with threadblocks lives in the BlockSpec index maps. ``interpret=True`` is
mandatory on this CPU-PJRT setup — real TPU lowering emits Mosaic
custom-calls the CPU plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _pick_block(l: int, preferred: int) -> int:
    """Largest divisor of ``l`` not exceeding ``preferred``."""
    b = min(preferred, l)
    while l % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# APM kernel: softmax(Q Kᵀ) materialised, q-tiled.
# ---------------------------------------------------------------------------

def _apm_kernel(q_ref, k_ref, o_ref, *, scale, causal, block_q):
    """One (batch, head, q-block) grid cell: [bq, dh] × [L, dh]ᵀ → [bq, L]."""
    q = q_ref[0, 0]                      # [bq, dh] VMEM tile
    k = k_ref[0, 0]                      # [L, dh] VMEM panel
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        qi = pl.program_id(2) * block_q + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0
        )
        ki = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(ki <= qi, s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    o_ref[0, 0] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def _apm_bias_kernel(q_ref, k_ref, bias_ref, o_ref, *, scale, causal, block_q):
    """Like :func:`_apm_kernel` plus an additive [bq, L] score bias
    (the DeBERTa-like disentangled relative-position term)."""
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = s + bias_ref[0].astype(jnp.float32)
    if causal:
        qi = pl.program_id(2) * block_q + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0
        )
        ki = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(ki <= qi, s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    o_ref[0, 0] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def apm_pallas(q, k, *, scale=None, causal=False, bias=None, block_q=32,
               interpret=True):
    """Attention probability matrix via Pallas.

    q, k: [B, nH, L, dh]; bias: optional [nH, L, L]. Returns [B, nH, L, L].
    """
    b, nh, l, dh = q.shape
    if scale is None:
        scale = 1.0 / float(dh) ** 0.5
    bq = _pick_block(l, block_q)
    grid = (b, nh, l // bq)
    q_spec = pl.BlockSpec((1, 1, bq, dh), lambda i, j, t: (i, j, t, 0))
    k_spec = pl.BlockSpec((1, 1, l, dh), lambda i, j, t: (i, j, 0, 0))
    o_spec = pl.BlockSpec((1, 1, bq, l), lambda i, j, t: (i, j, t, 0))
    out_shape = jax.ShapeDtypeStruct((b, nh, l, l), q.dtype)
    if bias is None:
        kern = functools.partial(_apm_kernel, scale=scale, causal=causal,
                                 block_q=bq)
        return pl.pallas_call(
            kern, grid=grid, in_specs=[q_spec, k_spec], out_specs=o_spec,
            out_shape=out_shape, interpret=interpret,
        )(q, k)
    bias_spec = pl.BlockSpec((1, bq, l), lambda i, j, t: (j, t, 0))
    kern = functools.partial(_apm_bias_kernel, scale=scale, causal=causal,
                             block_q=bq)
    return pl.pallas_call(
        kern, grid=grid, in_specs=[q_spec, k_spec, bias_spec], out_specs=o_spec,
        out_shape=out_shape, interpret=interpret,
    )(q, k, bias)


# ---------------------------------------------------------------------------
# Fused attention: streaming online softmax (FlashAttention schedule).
# ---------------------------------------------------------------------------

def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal,
                  block_q, block_k):
    """One (batch, head, q-block) cell: stream K/V panels in ``block_k``
    chunks with online-softmax rescaling; the [bq, L] score block never
    exists in full."""
    q = q_ref[0, 0]                       # [bq, dh]
    dh = q.shape[-1]
    l = k_ref.shape[2]
    nk = l // block_k
    q_off = pl.program_id(2) * block_q

    def body(i, carry):
        m_prev, l_prev, acc = carry
        kc = k_ref[0, 0, pl.ds(i * block_k, block_k), :]   # [bk, dh]
        vc = v_ref[0, 0, pl.ds(i * block_k, block_k), :]   # [bk, dh]
        s = jnp.dot(q, kc.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qi = q_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            ki = i * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(ki <= qi, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.dot(p, vc.astype(jnp.float32),
                                   preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((q.shape[0], 1), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((q.shape[0], 1), dtype=jnp.float32)
    acc0 = jnp.zeros((q.shape[0], dh), dtype=jnp.float32)
    _, l_fin, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / l_fin).astype(o_ref.dtype)


def attention_pallas(q, k, v, *, scale=None, causal=False, block_q=32,
                     block_k=64, interpret=True):
    """Fused attention context via Pallas.

    q, k, v: [B, nH, L, dh]. Returns [B, nH, L, dh] = softmax(QKᵀ)·V.
    """
    b, nh, l, dh = q.shape
    if scale is None:
        scale = 1.0 / float(dh) ** 0.5
    bq = _pick_block(l, block_q)
    bk = _pick_block(l, block_k)
    grid = (b, nh, l // bq)
    q_spec = pl.BlockSpec((1, 1, bq, dh), lambda i, j, t: (i, j, t, 0))
    kv_spec = pl.BlockSpec((1, 1, l, dh), lambda i, j, t: (i, j, 0, 0))
    o_spec = pl.BlockSpec((1, 1, bq, dh), lambda i, j, t: (i, j, t, 0))
    out_shape = jax.ShapeDtypeStruct((b, nh, l, dh), q.dtype)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             block_q=bq, block_k=bk)
    return pl.pallas_call(
        kern, grid=grid, in_specs=[q_spec, kv_spec, kv_spec], out_specs=o_spec,
        out_shape=out_shape, interpret=interpret,
    )(q, k, v)


def apply_apm_pallas(apm, v, *, interpret=True):
    """Context from a (possibly memoized) APM: [B,nH,L,L] · [B,nH,L,dh].

    This is the kernel the memoized path runs *instead of* score
    computation — the APM arrives from the attention database.
    """
    b, nh, l, dh = v.shape
    bq = _pick_block(l, 32)
    grid = (b, nh, l // bq)

    def kern(a_ref, v_ref, o_ref):
        a = a_ref[0, 0]                   # [bq, L]
        vv = v_ref[0, 0]                  # [L, dh]
        o_ref[0, 0] = jnp.dot(
            a, vv, preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)

    a_spec = pl.BlockSpec((1, 1, bq, l), lambda i, j, t: (i, j, t, 0))
    v_spec = pl.BlockSpec((1, 1, l, dh), lambda i, j, t: (i, j, 0, 0))
    o_spec = pl.BlockSpec((1, 1, bq, dh), lambda i, j, t: (i, j, t, 0))
    return pl.pallas_call(
        kern, grid=grid, in_specs=[a_spec, v_spec], out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((b, nh, l, dh), v.dtype),
        interpret=interpret,
    )(apm, v)
