"""L1 Pallas kernel for the AttMemo hidden-state embedding network.

The paper's embedding model (§5.2) is a lightweight 3-layer MLP mapping a
hidden state [L, H] to a 128-d feature vector; its L2 distances must predict
APM similarity (trained as a Siamese network, Fig. 6). Here the sequence is
first pooled into S segment means ([B, S·H], see ref.segment_pool_ref) and
the 3 affine layers + normalisation run as one Pallas kernel: the weight
panels (S·H×256, 256×256, 256×128 ≈ 1.3 MiB f32, ~0.7 MiB bf16) all fit in
VMEM simultaneously, so the kernel tiles only the batch dimension.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref


def _embed_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                  o_ref):
    """One batch tile through the full MLP; weights stay resident."""
    x = x_ref[...]
    h = jnp.maximum(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...], 0.0)
    h = jnp.maximum(
        jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...], 0.0)
    z = (jnp.dot(h, w3_ref[...], preferred_element_type=jnp.float32)
         + b3_ref[...])
    norm = jnp.sqrt(jnp.sum(z * z, axis=-1, keepdims=True) + 1e-12)
    o_ref[...] = (z / norm).astype(o_ref.dtype)


def mlp_embed_pallas(pooled, w1, b1, w2, b2, w3, b3, *, block_b=8,
                     interpret=True):
    """Run the embedding MLP: pooled [B, D_in] → [B, D_out], L2-normalised.

    Matches :func:`compile.kernels.ref.mlp_embed_ref`.
    """
    b, d_in = pooled.shape
    d_h1 = w1.shape[1]
    d_h2 = w2.shape[1]
    d_out = w3.shape[1]
    assert w1.shape == (d_in, d_h1) and w2.shape == (d_h1, d_h2)
    assert w3.shape == (d_h2, d_out)
    bb = min(block_b, b)
    while b % bb != 0:
        bb -= 1
    grid = (b // bb,)

    def whole(shape):
        return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    return pl.pallas_call(
        functools.partial(_embed_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d_in), lambda i: (i, 0)),
            whole((d_in, d_h1)), whole((d_h1,)),
            whole((d_h1, d_h2)), whole((d_h2,)),
            whole((d_h2, d_out)), whole((d_out,)),
        ],
        out_specs=pl.BlockSpec((bb, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d_out), pooled.dtype),
        interpret=interpret,
    )(pooled, w1, b1, w2, b2, w3, b3)


def embed_hidden(hidden, params, *, segments, interpret=True):
    """Full embedding path: [B, L, H] hidden → [B, 128] feature vectors."""
    pooled = _ref.segment_pool_ref(hidden, segments)
    w1, b1, w2, b2, w3, b3 = params
    return mlp_embed_pallas(pooled, w1, b1, w2, b2, w3, b3,
                            interpret=interpret)
