"""Pure-jnp reference oracles for every Pallas kernel.

These definitions are the correctness contract: pytest (and hypothesis
sweeps) assert that each kernel in this package matches its oracle to
float32 tolerance across shapes and dtypes. They are also reused by the L2
model as the non-kernel fallback path when ``ATTMEMO_NO_PALLAS=1``.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def apm_ref(q, k, *, scale=None, causal=False, bias=None):
    """Attention probability matrix: softmax(q·kᵀ·scale [+ bias] [+ mask]).

    q, k: [B, nH, L, dh]; bias (optional): [nH, L, L] broadcast over batch.
    Returns [B, nH, L, L] rows summing to 1.
    """
    dh = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if bias is not None:
        s = s + bias[None, :, :, :]
    if causal:
        ql, kl = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((ql, kl), dtype=bool))
        s = jnp.where(mask[None, None], s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def attention_ref(q, k, v, *, scale=None, causal=False, bias=None):
    """Fused attention: apm_ref(q,k) · v → [B, nH, L, dh]."""
    apm = apm_ref(q, k, scale=scale, causal=causal, bias=bias)
    return jnp.einsum("bhqk,bhkd->bhqd", apm, v)


def mlp_embed_ref(pooled, w1, b1, w2, b2, w3, b3):
    """AttMemo embedding network on pre-pooled features.

    pooled: [B, S*H]. Three affine layers with ReLU between (DESIGN.md notes
    the deviation from the paper's all-linear MLP, which is degenerate), then
    L2 normalisation so HNSW L2 distance is a cosine-style metric.
    """
    h = jnp.maximum(pooled @ w1 + b1, 0.0)
    h = jnp.maximum(h @ w2 + b2, 0.0)
    z = h @ w3 + b3
    norm = jnp.sqrt(jnp.sum(z * z, axis=-1, keepdims=True) + 1e-12)
    return z / norm


def segment_pool_ref(hidden, segments):
    """Pool [B, L, H] into [B, segments*H] by per-segment means.

    Keeps coarse positional structure (unlike a global mean) so the embedder
    can distinguish 'important word early' from 'important word late'.
    """
    b, l, h = hidden.shape
    assert l % segments == 0, f"L={l} not divisible by segments={segments}"
    seg = hidden.reshape(b, segments, l // segments, h).mean(axis=2)
    return seg.reshape(b, segments * h)


def similarity_ref(a, b):
    """Paper Eq. 1 similarity score between APM batches, averaged over heads.

    a, b: [N, nH, L, L] row-stochastic. Returns [N] in [0, 1]:
    ``1 - mean_p TV(a[p,:], b[p,:])`` with TV = 0.5·L1.
    """
    tv = 0.5 * jnp.sum(jnp.abs(a - b), axis=-1)  # [N, nH, L]
    return 1.0 - tv.mean(axis=(1, 2))


def layernorm_ref(x, g, b, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu_ref(x):
    """tanh-approximated GELU (matches jax.nn.gelu(approximate=True))."""
    return jax.nn.gelu(x, approximate=True)
