"""AOT build driver: data → training → HLO artifacts (`make artifacts`).

Runs ONCE at build time; the rust coordinator is self-contained afterwards.

Outputs under ``artifacts/``:

* ``vocab.json``, ``templates.json`` — shared corpus definition.
* ``datasets/*.bin``      — pre-generated train/test sets (ATDS format).
* ``weights/<fam>.bin``   — trained weights (model + AttMemo embedder),
  plus ``<fam>_sparse<NN>.bin`` pruned variants for the bert family.
* ``hlo/<fam>_<graph>_b<B>_s<L>.hlo.txt`` — lowered graphs, HLO TEXT
  (never ``.serialize()``: xla_extension 0.5.1 rejects jax≥0.5 64-bit-id
  protos; the text parser reassigns ids — see /opt/xla-example/README.md).
* ``fixtures/<fam>.bin``  — cross-language numeric test vectors.
* ``manifest.json``       — the single index the rust side loads.

Env knobs: ``ATTMEMO_FAST=1`` shrinks training/datasets for smoke runs;
``ATTMEMO_FAMILIES=bert,gpt`` restricts families.
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, io_utils, train
from . import model as M
from .config import (FAMILIES, ModelConfig, SERVING_BATCHES, SERVING_SEQ_LEN,
                     SWEEP_SEQ_LENS, TRAIN_SEQ_LEN)

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """Lowered jax → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fast() -> bool:
    return os.environ.get("ATTMEMO_FAST", "0") == "1"


def _families():
    env = os.environ.get("ATTMEMO_FAMILIES")
    if env:
        return tuple(f for f in env.split(",") if f)
    return FAMILIES


# ---------------------------------------------------------------------------
# Graph lowering
# ---------------------------------------------------------------------------

def graph_signature(cfg: ModelConfig, kind: str, batch: int, seq: int):
    """(callable, input specs, param-name list) for one graph kind."""
    h, nh = cfg.hidden, cfg.heads
    hid = spec((batch, seq, h))
    apm = spec((batch, nh, seq, seq))
    ln = [spec((h,)), spec((h,))]
    mat = lambda a, b: spec((a, b))
    layer_w = [
        mat(h, h), spec((h,)), mat(h, h), spec((h,)),       # wq bq wk bk
        mat(h, h), spec((h,)), mat(h, h), spec((h,)),       # wv bv wo bo
        *ln,                                                # ln1
        mat(h, cfg.ffn), spec((cfg.ffn,)),                  # wf1 bf1
        mat(cfg.ffn, h), spec((h,)),                        # wf2 bf2
        *ln,                                                # ln2
    ]
    rel = [mat(cfg.rel_pos_buckets, h)] if cfg.family == "deberta" else []

    if kind == "embed":
        fn = M.embed_graph(cfg)
        ins = [spec((batch, seq), I32), mat(cfg.vocab_size, h),
               mat(cfg.max_len, h), *ln]
        names = ["ids", "tok_emb", "pos_emb", "lne_g", "lne_b"]
    elif kind == "attn_scores":
        fn = M.attn_scores_graph(cfg)
        ins = [hid, mat(h, h), spec((h,)), mat(h, h), spec((h,)), *ln, *rel]
        names = ["hidden", "wq", "bq", "wk", "bk", "ln1_g", "ln1_b"] \
            + (["rel_emb"] if rel else [])
    elif kind == "attn_apply":
        fn = M.attn_apply_graph(cfg)
        ins = [hid, apm, *layer_w]
        names = ["hidden", "apm"] + list(M.LAYER_WEIGHTS)
    elif kind == "layer_full":
        fn = M.layer_full_graph(cfg)
        ins = [hid, *layer_w, *rel]
        names = ["hidden"] + list(M.LAYER_WEIGHTS) \
            + (["rel_emb"] if rel else [])
    elif kind == "classifier":
        fn = M.classifier_graph(cfg)
        ins = [hid, mat(h, h), spec((h,)), mat(h, cfg.num_classes),
               spec((cfg.num_classes,))]
        names = ["hidden"] + list(M.CLS_WEIGHTS)
    elif kind == "lm_head":
        fn = M.lm_head_graph(cfg)
        ins = [hid, mat(cfg.vocab_size, h)]
        names = ["hidden", "tok_emb"]
    elif kind == "mlp_embed":
        fn = M.mlp_embed_graph(cfg)
        d_in = cfg.embed_segments * h
        ins = [hid, mat(d_in, cfg.embed_hidden), spec((cfg.embed_hidden,)),
               mat(cfg.embed_hidden, cfg.embed_hidden),
               spec((cfg.embed_hidden,)),
               mat(cfg.embed_hidden, cfg.embed_dim), spec((cfg.embed_dim,))]
        names = ["hidden"] + list(M.EMBEDDER_WEIGHTS)
    else:
        raise ValueError(f"unknown graph kind {kind}")
    return fn, ins, names


def lower_graph(cfg, kind, batch, seq, out_path):
    fn, ins, names = graph_signature(cfg, kind, batch, seq)
    lowered = jax.jit(fn, keep_unused=True).lower(*ins)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return names, len(text)


def graph_plan(cfg: ModelConfig):
    """Which (kind, batch, seq) combos to lower for one family."""
    kinds = ["embed", "attn_scores", "attn_apply", "layer_full", "mlp_embed"]
    kinds.append("lm_head" if cfg.family == "gpt" else "classifier")
    plan = []
    serve_l = SERVING_SEQ_LEN
    for kind in kinds:
        for b in SERVING_BATCHES:
            plan.append((kind, b, serve_l))
    # Sequence-length sweep (Fig. 12 / Fig. 1): encoders at 64; bert also
    # at 16 and 32.
    sweep = []
    if cfg.family != "gpt":
        sweep.append(64)
    if cfg.family == "bert":
        sweep += [16, 32]
    for l in sweep:
        for kind in kinds:
            plan.append((kind, 8, l))
    return plan


# ---------------------------------------------------------------------------
# Build steps
# ---------------------------------------------------------------------------

def build(out_dir: str, log=print):
    t_start = time.time()
    fast = _fast()
    io_utils.ensure_dir(out_dir)
    for sub in ("hlo", "weights", "datasets", "fixtures"):
        io_utils.ensure_dir(os.path.join(out_dir, sub))

    # 1. Corpus ------------------------------------------------------------
    vocab = datagen.build_vocab()
    vocab_size = datagen.padded_vocab_size(vocab)
    datagen.export_vocab_and_templates(
        vocab, os.path.join(out_dir, "vocab.json"),
        os.path.join(out_dir, "templates.json"))

    n_train = 512 if fast else 4096
    n_test = 128 if fast else 640
    train_ids, train_labels = datagen.gen_classification(
        n_train, TRAIN_SEQ_LEN, 0, vocab)
    test_ids, test_labels = datagen.gen_classification(
        n_test, TRAIN_SEQ_LEN, 10_000, vocab)
    lm_ids, lm_labels = datagen.gen_lm(n_train // 2, TRAIN_SEQ_LEN, 1, vocab)
    lm_test_ids, lm_test_labels = datagen.gen_lm(
        n_test, TRAIN_SEQ_LEN, 10_001, vocab)
    # Serving-length sets (L=128) used by the rust engine and benches.
    serve_train_ids, serve_train_labels = datagen.gen_classification(
        n_train, SERVING_SEQ_LEN, 2, vocab)
    serve_test_ids, serve_test_labels = datagen.gen_classification(
        n_test, SERVING_SEQ_LEN, 10_002, vocab)
    serve_lm_ids, _ = datagen.gen_lm(n_train // 2, SERVING_SEQ_LEN, 3, vocab)
    serve_lm_test_ids, _ = datagen.gen_lm(
        n_test, SERVING_SEQ_LEN, 10_003, vocab)
    # Fig. 12 sweep sets.
    sweep_sets = {}
    for l in SWEEP_SEQ_LENS:
        sweep_sets[l] = datagen.gen_classification(
            256 if fast else 1024, l, 100 + l, vocab)

    datasets = {}

    def put_ds(name, ids, labels):
        p = os.path.join(out_dir, "datasets", name + ".bin")
        datagen.write_dataset(p, ids, labels)
        datasets[name] = {"path": f"datasets/{name}.bin",
                          "n": int(ids.shape[0]),
                          "seq_len": int(ids.shape[1])}

    put_ds("cls_train", train_ids, train_labels)
    put_ds("cls_test", test_ids, test_labels)
    put_ds("lm_train", lm_ids, lm_labels)
    put_ds("lm_test", lm_test_ids, lm_test_labels)
    put_ds("cls_train_serve", serve_train_ids, serve_train_labels)
    put_ds("cls_test_serve", serve_test_ids, serve_test_labels)
    put_ds("lm_train_serve", serve_lm_ids,
           np.zeros(serve_lm_ids.shape[0], np.int32))
    put_ds("lm_test_serve", serve_lm_test_ids,
           np.zeros(serve_lm_test_ids.shape[0], np.int32))
    for l, (i_, l_) in sweep_sets.items():
        put_ds(f"cls_sweep_{l}", i_, l_)
    log(f"[aot] corpus ready ({time.time()-t_start:.0f}s)")

    # 2. Training ----------------------------------------------------------
    os.environ["ATTMEMO_NO_PALLAS"] = "1"   # pure-jnp training fast path
    steps = 60 if fast else 600
    esteps = 60 if fast else 400
    fams = {}
    for fam in _families():
        cfg = ModelConfig(family=fam, vocab_size=vocab_size,
                          max_len=SERVING_SEQ_LEN)
        t0 = time.time()
        if fam == "gpt":
            tr_i, tr_l, te_i, te_l = lm_ids, lm_labels, lm_test_ids, \
                lm_test_labels
        else:
            tr_i, tr_l, te_i, te_l = train_ids, train_labels, test_ids, \
                test_labels
        lr = 1.5e-3 if fam == "gpt" else 7e-4
        params, hist = train.train_task(cfg, tr_i, tr_l, steps=steps, lr=lr,
                                        log=log)
        acc = train.eval_accuracy(cfg, params, te_i, te_l)
        train_secs = time.time() - t0
        log(f"[aot] {fam}: acc={acc:.4f} train={train_secs:.0f}s")

        # Embedder (Siamese) on a subsample of per-layer states.
        t0 = time.time()
        sub = tr_i[: (64 if fast else 256)]
        hiddens, apms = train.collect_states(cfg, params, sub)
        eparams, ehist = train.train_embedder(cfg, hiddens, apms,
                                              steps=esteps, log=log)
        embed_secs = time.time() - t0
        log(f"[aot] {fam}: embedder trained in {embed_secs:.0f}s")

        all_params = {**params, **eparams}
        order = M.param_order(cfg) + list(M.EMBEDDER_WEIGHTS)
        wpath = os.path.join(out_dir, "weights", f"{fam}.bin")
        entries = io_utils.write_tensor_bin(
            wpath, [(n, np.asarray(all_params[n])) for n in order])
        fams[fam] = {
            "config": cfg.to_dict(),
            "weights": f"weights/{fam}.bin",
            "tensors": entries,
            "accuracy": float(acc),
            "train_seconds": train_secs,
            "embedder_seconds": embed_secs,
            "final_loss": hist[-1],
            "embedder_final_loss": ehist[-1],
            "sparse_variants": [],
        }

        # Sparse variants (§6.8) — bert family only, three sparsities.
        if fam == "bert":
            for sp in (0.80, 0.85, 0.90):
                masks = train.prune_masks(params, sp)
                sparams = train.finetune_sparse(
                    cfg, params, masks, tr_i, tr_l,
                    steps=10 if fast else 80, log=log)
                sacc = train.eval_accuracy(cfg, sparams, te_i, te_l)
                tag = f"sparse{int(sp*100)}"
                sall = {**sparams, **eparams}
                spath = os.path.join(out_dir, "weights", f"{fam}_{tag}.bin")
                sentries = io_utils.write_tensor_bin(
                    spath, [(n, np.asarray(sall[n])) for n in order])
                fams[fam]["sparse_variants"].append({
                    "tag": tag, "sparsity": sp,
                    "realized_sparsity": train.sparsity_of(sparams),
                    "weights": f"weights/{fam}_{tag}.bin",
                    "tensors": sentries,
                    "accuracy": float(sacc),
                })
                log(f"[aot] {fam}-{tag}: acc={sacc:.4f}")

        # Fixtures: cross-language numeric test vectors (serving length, so
        # the rust side exercises the same graphs it serves with).
        fix_src = serve_lm_test_ids if fam == "gpt" else serve_test_ids
        fb, fl = 4, SERVING_SEQ_LEN
        fix_in = jnp.asarray(fix_src[:fb])
        hidden0 = M.embed_graph(cfg)(
            fix_in, *[jnp.asarray(params[n]) for n in M.EMBED_WEIGHTS])
        extra = [jnp.asarray(params["rel_emb"])] \
            if fam == "deberta" else []
        apm0 = M.attn_scores_graph(cfg)(
            hidden0,
            jnp.asarray(params["l0_wq"]), jnp.asarray(params["l0_bq"]),
            jnp.asarray(params["l0_wk"]), jnp.asarray(params["l0_bk"]),
            jnp.asarray(params["l0_ln1_g"]), jnp.asarray(params["l0_ln1_b"]),
            *extra)
        logits = M.forward_logits(cfg, params, fix_in)
        feat = M.mlp_embed_graph(cfg)(
            hidden0, *[jnp.asarray(eparams[n]) for n in M.EMBEDDER_WEIGHTS])
        fpath = os.path.join(out_dir, "fixtures", f"{fam}.bin")
        fentries = io_utils.write_tensor_bin(fpath, [
            ("ids", np.asarray(fix_in)),
            ("hidden0", np.asarray(hidden0)),
            ("apm0", np.asarray(apm0)),
            ("logits", np.asarray(logits)),
            ("feature0", np.asarray(feat)),
        ])
        fams[fam]["fixtures"] = {"path": f"fixtures/{fam}.bin",
                                 "tensors": fentries,
                                 "batch": fb, "seq_len": int(fl)}

    # 3. Graph lowering (Pallas kernels ON) ---------------------------------
    os.environ["ATTMEMO_NO_PALLAS"] = "0"
    graphs = []
    for fam, info in fams.items():
        cfg = ModelConfig(family=fam, vocab_size=vocab_size,
                          max_len=SERVING_SEQ_LEN)
        for kind, b, l in graph_plan(cfg):
            name = f"{fam}_{kind}_b{b}_s{l}"
            path = os.path.join(out_dir, "hlo", name + ".hlo.txt")
            t0 = time.time()
            names, nbytes = lower_graph(cfg, kind, b, l, path)
            graphs.append({
                "family": fam, "kind": kind, "batch": b, "seq_len": l,
                "path": f"hlo/{name}.hlo.txt", "params": names,
                "bytes": nbytes,
            })
            log(f"[aot] lowered {name} ({nbytes/1024:.0f} KiB, "
                f"{time.time()-t0:.1f}s)")

    manifest = {
        "version": 1,
        "vocab_size": vocab_size,
        "vocab": "vocab.json",
        "templates": "templates.json",
        "serving_seq_len": SERVING_SEQ_LEN,
        "serving_batches": list(SERVING_BATCHES),
        "sweep_seq_lens": list(SWEEP_SEQ_LENS),
        "train_seq_len": TRAIN_SEQ_LEN,
        "families": fams,
        "graphs": graphs,
        "datasets": datasets,
        "build_seconds": time.time() - t_start,
        "fast_mode": fast,
    }
    io_utils.write_manifest(os.path.join(out_dir, "manifest.json"), manifest)
    log(f"[aot] DONE in {time.time()-t_start:.0f}s → {out_dir}/manifest.json")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts output directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    sys.exit(main())
