"""Model-family configurations shared by the whole compile path.

Four tiny-scale families mirror the paper's evaluation models (Table 1):

* ``bert``    — post-LN encoder, learned positions, tanh pooler (BERT-like).
* ``roberta`` — pre-LN encoder, scaled embeddings, GELU FFN (RoBERTa-like).
* ``deberta`` — encoder with a disentangled relative-position attention term
  (DeBERTa-like); attention is deliberately more expensive, reproducing the
  paper's observation that DeBERTa benefits most from memoization.
* ``gpt``     — causal decoder with a tied LM head (GPT-2-like).

The paper's models are ~110M parameters; these are ~1-2M because the
evaluation box has a single CPU core and Pallas runs under interpret=True.
All claims reproduced downstream are ratios, not absolute times
(DESIGN.md §2).
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters for one transformer family."""

    family: str                 # bert | roberta | deberta | gpt
    vocab_size: int = 1024      # padded to a round number after datagen
    hidden: int = 128           # H; one MXU tile wide on real TPU
    layers: int = 4
    heads: int = 4
    ffn: int = 256
    max_len: int = 128
    num_classes: int = 2        # sentiment polarity (encoder families)
    rel_pos_buckets: int = 32   # deberta only: relative-position range 2R
    embed_dim: int = 128        # AttMemo embedding-network output dim
    embed_hidden: int = 256     # AttMemo embedding-network hidden width
    embed_segments: int = 8     # sequence pooled into S segments pre-MLP

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def causal(self) -> bool:
        return self.family == "gpt"

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["causal"] = self.causal
        return d


FAMILIES = ("bert", "roberta", "deberta", "gpt")


def config_for(family: str) -> ModelConfig:
    """Canonical config for a family name."""
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}, expected one of {FAMILIES}")
    return ModelConfig(family=family)


# Batch sizes and sequence lengths lowered by aot.py. Batch {1,8,32} is the
# scaled analogue of the paper's {1,32,64}; sequence lengths cover the
# Fig. 12 sweep plus the serving length (128 ~ paper's 512/1024).
SERVING_BATCHES = (1, 8, 32)
SERVING_SEQ_LEN = 128
SWEEP_SEQ_LENS = (16, 32, 64, 128)
TRAIN_SEQ_LEN = 64
