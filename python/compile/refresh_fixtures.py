"""Regenerate the cross-language fixtures from already-trained weights.

Maintenance utility: recomputes `fixtures/<family>.bin` (ids, hidden0,
apm0, logits, feature0 at the serving sequence length) from the weight
bins referenced by `manifest.json`, then patches the manifest in place.
Much cheaper than a full `make artifacts` when only fixtures changed.

Usage: cd python && python -m compile.refresh_fixtures ../artifacts
"""

import json
import os
import sys

import jax.numpy as jnp
import numpy as np

from . import io_utils
from . import model as M
from .config import ModelConfig


def refresh(out_dir: str) -> None:
    os.environ["ATTMEMO_NO_PALLAS"] = "1"  # oracle path; equivalence tested
    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    seq_len = manifest["serving_seq_len"]

    for fam, info in manifest["families"].items():
        cfg = ModelConfig(**{
            k: v for k, v in info["config"].items()
            if k not in ("head_dim", "causal")
        })
        weights = io_utils.read_tensor_bin(
            os.path.join(out_dir, info["weights"]), info["tensors"])
        params = {k: jnp.asarray(v) for k, v in weights.items()}

        ds = manifest["datasets"][
            "lm_test_serve" if fam == "gpt" else "cls_test_serve"]
        raw = np.fromfile(os.path.join(out_dir, ds["path"]), dtype=np.uint8)
        n, sl = np.frombuffer(raw[4:12], "<u4")
        ids = np.frombuffer(
            raw[12:12 + n * sl * 4], "<i4").reshape(n, sl)[:4]
        assert sl == seq_len, (sl, seq_len)
        fix_in = jnp.asarray(ids)

        hidden0 = M.embed_graph(cfg)(
            fix_in, *[params[k] for k in M.EMBED_WEIGHTS])
        extra = [params["rel_emb"]] if fam == "deberta" else []
        apm0 = M.attn_scores_graph(cfg)(
            hidden0, params["l0_wq"], params["l0_bq"], params["l0_wk"],
            params["l0_bk"], params["l0_ln1_g"], params["l0_ln1_b"], *extra)
        logits = M.forward_logits(cfg, params, fix_in)
        feat = M.mlp_embed_graph(cfg)(
            hidden0, *[params[k] for k in M.EMBEDDER_WEIGHTS])

        fpath = os.path.join(out_dir, "fixtures", f"{fam}.bin")
        entries = io_utils.write_tensor_bin(fpath, [
            ("ids", np.asarray(fix_in)),
            ("hidden0", np.asarray(hidden0)),
            ("apm0", np.asarray(apm0)),
            ("logits", np.asarray(logits)),
            ("feature0", np.asarray(feat)),
        ])
        info["fixtures"] = {"path": f"fixtures/{fam}.bin",
                            "tensors": entries,
                            "batch": 4, "seq_len": int(seq_len)}
        print(f"[fixtures] refreshed {fam} at seq_len {seq_len}")

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[fixtures] manifest updated: {manifest_path}")


if __name__ == "__main__":
    refresh(sys.argv[1] if len(sys.argv) > 1 else "../artifacts")
