"""Re-lower existing manifest graphs after an L2 graph change (§Perf).

Weights are runtime arguments, so graph changes never require retraining —
this utility re-lowers the named graph kinds for every (family, batch,
seq-len) combination already present in `manifest.json`, in place.

Usage: cd python && python -m compile.relower ../artifacts [kind ...]
"""

import json
import os
import sys

from . import aot
from .config import ModelConfig


def relower(out_dir: str, kinds) -> None:
    os.environ["ATTMEMO_NO_PALLAS"] = "0"
    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    cfgs = {
        fam: ModelConfig(**{
            k: v for k, v in info["config"].items()
            if k not in ("head_dim", "causal")
        })
        for fam, info in manifest["families"].items()
    }
    count = 0
    for g in manifest["graphs"]:
        if g["kind"] not in kinds:
            continue
        path = os.path.join(out_dir, g["path"])
        names, nbytes = aot.lower_graph(
            cfgs[g["family"]], g["kind"], g["batch"], g["seq_len"], path)
        g["params"] = names
        g["bytes"] = nbytes
        count += 1
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[relower] {count} graphs re-lowered for kinds {sorted(kinds)}")


if __name__ == "__main__":
    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    kinds = set(sys.argv[2:]) or {"attn_apply"}
    relower(out, kinds)
