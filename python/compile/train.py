"""Build-time training loops (hand-rolled Adam; optax is not available).

Trains, per family:
  1. the task model (sentiment classification, or next-token LM for gpt);
  2. the AttMemo Siamese embedding MLP (Fig. 6): pairs of per-layer hidden
     states, ground truth = Eq. 1 similarity of their APMs, loss =
     (‖e(x)−e(y)‖₂ − (1 − sc))² so embedding distance predicts APM
     similarity;
  3. magnitude-pruned sparse variants (§6.8) with mask-preserving finetune.

Training runs with ``ATTMEMO_NO_PALLAS=1`` (pure-jnp attention) for speed;
kernel/oracle equivalence is enforced by pytest, and the *shipped* HLO
artifacts are lowered with the Pallas kernels enabled.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .config import ModelConfig
from .kernels import ref


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Task training
# ---------------------------------------------------------------------------

def _cls_loss(cfg, params, ids, labels):
    logits = M.forward_logits(cfg, params, ids)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def _lm_loss(cfg, params, ids):
    logits = M.forward_logits(cfg, params, ids)  # [B, L, V]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = ids[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    mask = (tgt != 0).astype(jnp.float32)
    return (nll * mask).sum() / mask.sum()


def train_task(cfg: ModelConfig, ids, labels, *, steps=800, batch=32,
               lr=7e-4, seed=0, log_every=100, log=print):
    """Train one family; returns (params, loss history).

    Post-LN families are slow starters, so residual output projections are
    down-scaled at init (GPT-2-style 1/sqrt(2·layers)) and the LR ramps
    linearly over the first 10% of steps.
    """
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    resid_scale = 1.0 / (2.0 * cfg.layers) ** 0.5
    for name in list(params):
        if name.startswith("l") and (name.endswith("wo")
                                     or name.endswith("wf2")):
            params[name] = params[name] * resid_scale
    opt = adam_init(params)
    n = ids.shape[0]
    warmup = max(1, steps // 10)

    if cfg.family == "gpt":
        loss_fn = lambda p, i, l: _lm_loss(cfg, p, i)
    else:
        loss_fn = lambda p, i, l: _cls_loss(cfg, p, i, l)

    @jax.jit
    def step(params, opt, i, l, lr_t):
        loss, grads = jax.value_and_grad(loss_fn)(params, i, l)
        params, opt = adam_update(params, grads, opt, lr=lr_t)
        return params, opt, loss

    rng = np.random.default_rng(seed)
    history = []
    for s in range(steps):
        lr_t = lr * min(1.0, (s + 1) / warmup)
        idx = rng.integers(0, n, size=batch)
        params, opt, loss = step(params, opt, jnp.asarray(ids[idx]),
                                 jnp.asarray(labels[idx]), lr_t)
        if s % log_every == 0 or s == steps - 1:
            log(f"  [{cfg.family}] step {s:4d} loss {float(loss):.4f}")
        history.append(float(loss))
    return params, history


def eval_accuracy(cfg: ModelConfig, params, ids, labels, batch=32):
    """Classification accuracy (encoders) or next-token accuracy (gpt)."""
    correct = total = 0
    fwd = jax.jit(lambda i: M.forward_logits(cfg, params, i))
    for s in range(0, ids.shape[0], batch):
        chunk = jnp.asarray(ids[s:s + batch])
        logits = fwd(chunk)
        if cfg.family == "gpt":
            pred = jnp.argmax(logits[:, :-1], axis=-1)
            tgt = chunk[:, 1:]
            mask = tgt != 0
            correct += int(((pred == tgt) & mask).sum())
            total += int(mask.sum())
        else:
            pred = jnp.argmax(logits, axis=-1)
            correct += int((pred == jnp.asarray(labels[s:s + batch])).sum())
            total += chunk.shape[0]
    return correct / max(total, 1)


# ---------------------------------------------------------------------------
# Hidden-state / APM collection (DB building + Siamese training data)
# ---------------------------------------------------------------------------

def collect_states(cfg: ModelConfig, params, ids, batch=16):
    """Per-layer (hidden, APM) for every sequence.

    Returns hiddens [layers, N, L, H] and apms [layers, N, nH, L, L]
    (numpy, float32).
    """
    fwd = jax.jit(functools.partial(_collect_fwd, cfg), static_argnums=())

    hs, ams = [], []
    for s in range(0, ids.shape[0], batch):
        chunk = jnp.asarray(ids[s:s + batch])
        h_layers, a_layers = _collect_fwd(cfg, params, chunk)
        hs.append(np.stack([np.asarray(h) for h in h_layers], axis=0))
        ams.append(np.stack([np.asarray(a) for a in a_layers], axis=0))
    return np.concatenate(hs, axis=1), np.concatenate(ams, axis=1)


def _collect_fwd(cfg, params, ids):
    _, collected = M.forward_hidden(cfg, params, ids, collect=True)
    return [c[0] for c in collected], [c[1] for c in collected]


# ---------------------------------------------------------------------------
# Siamese embedder training (paper §5.2, Fig. 6)
# ---------------------------------------------------------------------------

def train_embedder(cfg: ModelConfig, hiddens, apms, *, steps=400, batch=64,
                   lr=1e-3, seed=0, log_every=100, log=print):
    """Train the embedding MLP on (hidden, hidden') pairs across all layers.

    hiddens: [layers, N, L, H]; apms: [layers, N, nH, L, L].
    Ground truth per pair = similarity_ref of their APMs; target embedding
    distance = 1 − similarity.
    """
    eparams = M.init_embedder(cfg, jax.random.PRNGKey(seed + 17))
    opt = adam_init(eparams)
    layers, n = hiddens.shape[0], hiddens.shape[1]

    def embed(p, h):
        pooled = ref.segment_pool_ref(h, cfg.embed_segments)
        return ref.mlp_embed_ref(pooled, p["e_w1"], p["e_b1"], p["e_w2"],
                                 p["e_b2"], p["e_w3"], p["e_b3"])

    def loss_fn(p, ha, hb, sc):
        ea, eb = embed(p, ha), embed(p, hb)
        d = jnp.sqrt(jnp.sum((ea - eb) ** 2, axis=-1) + 1e-12)
        return jnp.mean((d - (1.0 - sc)) ** 2)

    @jax.jit
    def step(p, opt, ha, hb, sc):
        loss, grads = jax.value_and_grad(loss_fn)(p, ha, hb, sc)
        p, opt = adam_update(p, grads, opt, lr=lr)
        return p, opt, loss

    rng = np.random.default_rng(seed)
    history = []
    for s in range(steps):
        li = rng.integers(0, layers)
        ia = rng.integers(0, n, size=batch)
        ib = rng.integers(0, n, size=batch)
        ha = jnp.asarray(hiddens[li, ia])
        hb = jnp.asarray(hiddens[li, ib])
        sc = ref.similarity_ref(jnp.asarray(apms[li, ia]),
                                jnp.asarray(apms[li, ib]))
        eparams, opt, loss = step(eparams, opt, ha, hb, sc)
        if s % log_every == 0 or s == steps - 1:
            log(f"  [{cfg.family}-embedder] step {s:4d} loss {float(loss):.5f}")
        history.append(float(loss))
    return eparams, history


# ---------------------------------------------------------------------------
# Magnitude pruning (§6.8)
# ---------------------------------------------------------------------------

PRUNABLE_SUFFIXES = ("wq", "wk", "wv", "wo", "wf1", "wf2")


def prune_masks(params, sparsity):
    """Per-tensor magnitude masks over the prunable layer matrices."""
    masks = {}
    for name, w in params.items():
        if any(name.endswith(s) for s in PRUNABLE_SUFFIXES) \
                and name.startswith("l"):
            k = int(w.size * sparsity)
            thresh = jnp.sort(jnp.abs(w).reshape(-1))[k]
            masks[name] = (jnp.abs(w) >= thresh).astype(w.dtype)
    return masks


def apply_masks(params, masks):
    out = dict(params)
    for name, m in masks.items():
        out[name] = params[name] * m
    return out


def finetune_sparse(cfg: ModelConfig, params, masks, ids, labels, *,
                    steps=60, batch=16, lr=5e-4, seed=1, log=print):
    """Finetune with masks re-applied after every update (dense grads,
    masked weights — the standard prune-then-finetune recipe)."""
    params = apply_masks(params, masks)
    opt = adam_init(params)
    if cfg.family == "gpt":
        loss_fn = lambda p, i, l: _lm_loss(cfg, p, i)
    else:
        loss_fn = lambda p, i, l: _cls_loss(cfg, p, i, l)

    @jax.jit
    def step(params, opt, i, l):
        loss, grads = jax.value_and_grad(loss_fn)(params, i, l)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    rng = np.random.default_rng(seed)
    n = ids.shape[0]
    for s in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, opt, loss = step(params, opt, jnp.asarray(ids[idx]),
                                 jnp.asarray(labels[idx]))
        params = apply_masks(params, masks)
        if s == steps - 1:
            log(f"  [{cfg.family}-sparse] final loss {float(loss):.4f}")
    return params


def sparsity_of(params):
    """Realised sparsity over the prunable matrices."""
    zero = total = 0
    for name, w in params.items():
        if any(name.endswith(s) for s in PRUNABLE_SUFFIXES) \
                and name.startswith("l"):
            zero += int((w == 0).sum())
            total += int(w.size)
    return zero / max(total, 1)
