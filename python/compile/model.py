"""L2 JAX model graphs for the four transformer families.

Every graph is a pure function over ``(activations, *weights)`` with weights
as *runtime arguments* (not baked constants): the rust runtime uploads each
family's weights once as PJRT device buffers, so a single lowered HLO per
(graph kind, batch, seq-len) serves all layers of a model.

Graph inventory (DESIGN.md §3):

* ``embed``        ids → hidden                       (token+position embed)
* ``attn_scores``  hidden → APM[B,nH,L,L]             (the memoization subject)
* ``attn_apply``   hidden, APM → hidden'              (memoized-path remainder)
* ``layer_full``   hidden → hidden'                   (fused non-memoized path)
* ``classifier``   hidden → logits[B,C]               (encoder families)
* ``lm_head``      hidden → logits[B,L,V]             (gpt family)
* ``mlp_embed``    hidden → feature[B,128]            (AttMemo embedder)

Family deltas: bert/deberta are post-LN, roberta/gpt are pre-LN; roberta
scales embeddings by sqrt(H); deberta adds disentangled relative-position
terms (c2p + p2c) to the attention scores; gpt is causal with a tied LM
head. Kernels come from ``compile.kernels`` (Pallas); set
``ATTMEMO_NO_PALLAS=1`` to swap in the pure-jnp oracles (used to speed up
training — equivalence is asserted by pytest).
"""

import os

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import attention as attk
from .kernels import mlp_embed as embk
from .kernels import ref


def _use_pallas() -> bool:
    return os.environ.get("ATTMEMO_NO_PALLAS", "0") != "1"


# Per-layer weight names, in the exact order every graph takes them.
LAYER_WEIGHTS = (
    "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "ln1_g", "ln1_b", "wf1", "bf1", "wf2", "bf2", "ln2_g", "ln2_b",
)
EMBED_WEIGHTS = ("tok_emb", "pos_emb", "lne_g", "lne_b")
CLS_WEIGHTS = ("pool_w", "pool_b", "cls_w", "cls_b")
EMBEDDER_WEIGHTS = ("e_w1", "e_b1", "e_w2", "e_b2", "e_w3", "e_b3")


def is_pre_ln(cfg: ModelConfig) -> bool:
    return cfg.family in ("roberta", "gpt")


def _split_heads(x, cfg: ModelConfig):
    b, l, _ = x.shape
    return x.reshape(b, l, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, nh, l, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, nh * dh)


def _rel_index(l: int, buckets: int):
    """Clipped relative-position index matrix rel[i, j] in [0, buckets)."""
    i = jnp.arange(l)[:, None]
    j = jnp.arange(l)[None, :]
    r = buckets // 2
    return jnp.clip(i - j + r, 0, buckets - 1)


def _deberta_bias(q, k, rel_emb, wq, wk, cfg: ModelConfig):
    """Disentangled attention terms (DeBERTa-like, batch-dependent).

    c2p[b,h,i,j] = Q[b,h,i,:]·Pk[h,rel(i,j),:] and
    p2c[b,h,i,j] = K[b,h,j,:]·Pq[h,rel(j,i),:], where Pq/Pk are the shared
    relative-position table projected through the layer's own Wq/Wk.
    Returns [B, nH, L, L] to add to the content scores before softmax.
    """
    l = q.shape[2]
    buckets = rel_emb.shape[0]
    pk = _split_heads((rel_emb @ wk)[None], cfg)[0]      # [nH, R, dh]
    pq = _split_heads((rel_emb @ wq)[None], cfg)[0]      # [nH, R, dh]
    rel = _rel_index(l, buckets)                          # [L, L]
    c2p_all = jnp.einsum("bhid,hrd->bhir", q, pk)         # [B,nH,L,R]
    c2p = jnp.take_along_axis(c2p_all, rel[None, None], axis=-1)
    p2c_all = jnp.einsum("bhjd,hrd->bhjr", k, pq)         # [B,nH,L,R]
    p2c = jnp.take_along_axis(p2c_all, rel.T[None, None], axis=-1)
    p2c = p2c.transpose(0, 1, 3, 2)                       # [B,nH,L,L]
    scale = 1.0 / (3.0 * cfg.head_dim) ** 0.5
    return (c2p + p2c) * scale


def _attn_input(hidden, ln1_g, ln1_b, cfg: ModelConfig):
    """Pre-LN families attend over LN(hidden); post-LN over hidden itself."""
    if is_pre_ln(cfg):
        return ref.layernorm_ref(hidden, ln1_g, ln1_b)
    return hidden


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------

def embed_graph(cfg: ModelConfig):
    """ids [B, L] i32 → hidden [B, L, H]."""

    def fn(ids, tok_emb, pos_emb, lne_g, lne_b):
        l = ids.shape[1]
        x = tok_emb[ids] + pos_emb[:l][None]
        if cfg.family == "roberta":
            x = x * (cfg.hidden ** 0.5)
        if cfg.family != "gpt":          # gpt uses no embedding LayerNorm
            x = ref.layernorm_ref(x, lne_g, lne_b)
        return x

    return fn


def attn_scores_graph(cfg: ModelConfig):
    """hidden [+layer weights] → APM [B, nH, L, L]. The memoization subject.

    Takes the full per-layer weight tuple (unused tails kept so one
    signature serves every family; lower with keep_unused=True) plus, for
    deberta, the shared relative-position table as the last argument.
    """
    scale = 1.0 / cfg.head_dim ** 0.5

    def fn(hidden, wq, bq, wk, bk, ln1_g, ln1_b, *rest):
        x = _attn_input(hidden, ln1_g, ln1_b, cfg)
        q = _split_heads(x @ wq + bq, cfg)
        k = _split_heads(x @ wk + bk, cfg)
        bias = None
        if cfg.family == "deberta":
            (rel_emb,) = rest
            bias = _deberta_bias(q, k, rel_emb, wq, wk, cfg)
        if _use_pallas():
            if bias is None:
                return attk.apm_pallas(q, k, scale=scale, causal=cfg.causal)
            # Batch-dependent bias: fold batch into the head axis so the
            # [nH,L,L]-bias kernel variant applies.
            return _apm_with_batch_bias(q, k, bias, scale, cfg.causal)
        if bias is None:
            return ref.apm_ref(q, k, scale=scale, causal=cfg.causal)
        return _apm_batch_bias_ref(q, k, bias, scale, cfg.causal)

    return fn


def _apm_batch_bias_ref(q, k, bias, scale, causal):
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale + bias
    if causal:
        l = s.shape[-1]
        mask = jnp.tril(jnp.ones((l, l), dtype=bool))
        s = jnp.where(mask[None, None], s, ref.NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _apm_with_batch_bias(q, k, bias, scale, causal):
    """Pallas APM with a batch-dependent bias: reuse the [nH,L,L]-bias kernel
    by folding the batch into the head axis."""
    b, nh, l, dh = q.shape
    qf = q.reshape(1, b * nh, l, dh)
    kf = k.reshape(1, b * nh, l, dh)
    bf = bias.reshape(b * nh, l, l)
    apm = attk.apm_pallas(qf, kf, scale=scale, causal=causal, bias=bf)
    return apm.reshape(b, nh, l, l)


def attn_apply_graph(cfg: ModelConfig):
    """(hidden, APM, layer weights) → next hidden.

    The APM argument is either freshly computed by ``attn_scores`` or fetched
    from the attention database — this graph is the shared remainder of the
    layer: V projection, context, output projection, residuals, FFN.
    """

    def fn(hidden, apm, wq, bq, wk, bk, wv, bv, wo, bo, ln1_g, ln1_b,
           wf1, bf1, wf2, bf2, ln2_g, ln2_b):
        x = hidden
        a_in = _attn_input(x, ln1_g, ln1_b, cfg)
        v = _split_heads(a_in @ wv + bv, cfg)
        # §Perf: APM·V here is a plain batched GEMM over an *input* APM —
        # XLA's native dot beats the interpret-mode Pallas grid loop by ~2×
        # on CPU-PJRT. The paper's attention hot-spot (scores / fused
        # softmax·V) stays in the Pallas kernels of attn_scores/layer_full.
        ctx = jnp.einsum("bhqk,bhkd->bhqd", apm, v)
        attn_out = _merge_heads(ctx) @ wo + bo
        if is_pre_ln(cfg):
            x = x + attn_out
            h = ref.layernorm_ref(x, ln2_g, ln2_b)
            x = x + (ref.gelu_ref(h @ wf1 + bf1) @ wf2 + bf2)
        else:
            x = ref.layernorm_ref(x + attn_out, ln1_g, ln1_b)
            x = ref.layernorm_ref(
                x + (ref.gelu_ref(x @ wf1 + bf1) @ wf2 + bf2), ln2_g, ln2_b)
        return x

    return fn


def layer_full_graph(cfg: ModelConfig):
    """(hidden, layer weights [, rel_emb]) → next hidden, fused fast path.

    Uses the streaming FlashAttention kernel — the L×L APM never
    materialises. deberta needs the explicit-bias score path instead.
    """
    scale = 1.0 / cfg.head_dim ** 0.5

    def fn(hidden, wq, bq, wk, bk, wv, bv, wo, bo, ln1_g, ln1_b,
           wf1, bf1, wf2, bf2, ln2_g, ln2_b, *rest):
        x = hidden
        a_in = _attn_input(x, ln1_g, ln1_b, cfg)
        q = _split_heads(a_in @ wq + bq, cfg)
        k = _split_heads(a_in @ wk + bk, cfg)
        v = _split_heads(a_in @ wv + bv, cfg)
        if cfg.family == "deberta":
            (rel_emb,) = rest
            bias = _deberta_bias(q, k, rel_emb, wq, wk, cfg)
            if _use_pallas():
                apm = _apm_with_batch_bias(q, k, bias, scale, cfg.causal)
                ctx = attk.apply_apm_pallas(apm, v)
            else:
                apm = _apm_batch_bias_ref(q, k, bias, scale, cfg.causal)
                ctx = jnp.einsum("bhqk,bhkd->bhqd", apm, v)
        elif _use_pallas():
            ctx = attk.attention_pallas(q, k, v, scale=scale,
                                        causal=cfg.causal)
        else:
            ctx = ref.attention_ref(q, k, v, scale=scale, causal=cfg.causal)
        attn_out = _merge_heads(ctx) @ wo + bo
        if is_pre_ln(cfg):
            x = x + attn_out
            h = ref.layernorm_ref(x, ln2_g, ln2_b)
            x = x + (ref.gelu_ref(h @ wf1 + bf1) @ wf2 + bf2)
        else:
            x = ref.layernorm_ref(x + attn_out, ln1_g, ln1_b)
            x = ref.layernorm_ref(
                x + (ref.gelu_ref(x @ wf1 + bf1) @ wf2 + bf2), ln2_g, ln2_b)
        return x

    return fn


def classifier_graph(cfg: ModelConfig):
    """hidden → logits [B, num_classes] via CLS-token tanh pooler."""

    def fn(hidden, pool_w, pool_b, cls_w, cls_b):
        pooled = jnp.tanh(hidden[:, 0] @ pool_w + pool_b)
        return pooled @ cls_w + cls_b

    return fn


def lm_head_graph(cfg: ModelConfig):
    """hidden → next-token logits [B, L, V] with tied embeddings."""

    def fn(hidden, tok_emb):
        return hidden @ tok_emb.T

    return fn


def mlp_embed_graph(cfg: ModelConfig):
    """hidden → L2-normalised feature [B, embed_dim] (AttMemo embedder)."""

    def fn(hidden, e_w1, e_b1, e_w2, e_b2, e_w3, e_b3):
        pooled = ref.segment_pool_ref(hidden, cfg.embed_segments)
        if _use_pallas():
            return embk.mlp_embed_pallas(pooled, e_w1, e_b1, e_w2, e_b2,
                                         e_w3, e_b3)
        return ref.mlp_embed_ref(pooled, e_w1, e_b1, e_w2, e_b2, e_w3, e_b3)

    return fn


# ---------------------------------------------------------------------------
# Whole-model forward (training / fixtures; not lowered for serving)
# ---------------------------------------------------------------------------

def forward_hidden(cfg: ModelConfig, params, ids, *, collect=False):
    """Run embed + all layers. Returns final hidden, plus per-layer
    (input_hidden, APM) pairs when ``collect`` (DB building / fixtures)."""
    emb = embed_graph(cfg)
    scores = attn_scores_graph(cfg)
    apply_ = attn_apply_graph(cfg)
    x = emb(ids, *[params[n] for n in EMBED_WEIGHTS])
    collected = []
    for li in range(cfg.layers):
        lw = [params[f"l{li}_{n}"] for n in LAYER_WEIGHTS]
        extra = [params["rel_emb"]] if cfg.family == "deberta" else []
        score_args = [lw[0], lw[1], lw[2], lw[3], lw[8], lw[9]] + extra
        apm = scores(x, *score_args)
        if collect:
            collected.append((x, apm))
        x = apply_(x, apm, *lw)
    return (x, collected) if collect else x


def forward_logits(cfg: ModelConfig, params, ids):
    """Full task forward: classifier logits (encoders) or LM logits (gpt)."""
    x = forward_hidden(cfg, params, ids)
    if cfg.family == "gpt":
        return lm_head_graph(cfg)(x, params["tok_emb"])
    return classifier_graph(cfg)(x, *[params[n] for n in CLS_WEIGHTS])


# ---------------------------------------------------------------------------
# Parameter initialisation & flattening
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    """Gaussian-init parameter dict for one family (training start point)."""
    h, f, v = cfg.hidden, cfg.ffn, cfg.vocab_size

    def nrm(key, shape, std):
        return jax.random.normal(key, shape, jnp.float32) * std

    keys = iter(jax.random.split(key, 256))
    p = {
        "tok_emb": nrm(next(keys), (v, h), 0.02),
        "pos_emb": nrm(next(keys), (cfg.max_len, h), 0.02),
        "lne_g": jnp.ones((h,)), "lne_b": jnp.zeros((h,)),
        "pool_w": nrm(next(keys), (h, h), 0.02), "pool_b": jnp.zeros((h,)),
        "cls_w": nrm(next(keys), (h, cfg.num_classes), 0.02),
        "cls_b": jnp.zeros((cfg.num_classes,)),
    }
    if cfg.family == "deberta":
        p["rel_emb"] = nrm(next(keys), (cfg.rel_pos_buckets, h), 0.02)
    for li in range(cfg.layers):
        p[f"l{li}_wq"] = nrm(next(keys), (h, h), 0.02)
        p[f"l{li}_bq"] = jnp.zeros((h,))
        p[f"l{li}_wk"] = nrm(next(keys), (h, h), 0.02)
        p[f"l{li}_bk"] = jnp.zeros((h,))
        p[f"l{li}_wv"] = nrm(next(keys), (h, h), 0.02)
        p[f"l{li}_bv"] = jnp.zeros((h,))
        p[f"l{li}_wo"] = nrm(next(keys), (h, h), 0.02)
        p[f"l{li}_bo"] = jnp.zeros((h,))
        p[f"l{li}_ln1_g"] = jnp.ones((h,))
        p[f"l{li}_ln1_b"] = jnp.zeros((h,))
        p[f"l{li}_wf1"] = nrm(next(keys), (h, f), 0.02)
        p[f"l{li}_bf1"] = jnp.zeros((f,))
        p[f"l{li}_wf2"] = nrm(next(keys), (f, h), 0.02)
        p[f"l{li}_bf2"] = jnp.zeros((h,))
        p[f"l{li}_ln2_g"] = jnp.ones((h,))
        p[f"l{li}_ln2_b"] = jnp.zeros((h,))
    return p


def init_embedder(cfg: ModelConfig, key):
    """Init the AttMemo embedding MLP (segment-pooled input)."""
    d_in = cfg.embed_segments * cfg.hidden
    k1, k2, k3 = jax.random.split(key, 3)

    def glorot(key, shape):
        lim = (6.0 / (shape[0] + shape[1])) ** 0.5
        return jax.random.uniform(key, shape, jnp.float32, -lim, lim)

    return {
        "e_w1": glorot(k1, (d_in, cfg.embed_hidden)),
        "e_b1": jnp.zeros((cfg.embed_hidden,)),
        "e_w2": glorot(k2, (cfg.embed_hidden, cfg.embed_hidden)),
        "e_b2": jnp.zeros((cfg.embed_hidden,)),
        "e_w3": glorot(k3, (cfg.embed_hidden, cfg.embed_dim)),
        "e_b3": jnp.zeros((cfg.embed_dim,)),
    }


def param_order(cfg: ModelConfig):
    """Deterministic weight order for the manifest / rust weight loader."""
    names = list(EMBED_WEIGHTS)
    if cfg.family == "deberta":
        names.append("rel_emb")
    for li in range(cfg.layers):
        names += [f"l{li}_{n}" for n in LAYER_WEIGHTS]
    names += list(CLS_WEIGHTS)
    return names
